"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar).

mLSTM — per head, stabilized exponential gating:
    C_t = f'_t C_{t-1} + i'_t k_t v_t^T      (dk, dv) matrix memory
    n_t = f'_t n_{t-1} + i'_t k_t
    h_t = (q_t^T C_t) / max(|q_t^T n_t|, exp(-m_t))
with running stabilizer m_t = max(log f_t + m_{t-1}, log i_t),
i'_t = exp(log i_t - m_t), f'_t = exp(log f_t + m_{t-1} - m_t).

Training uses the **chunkwise-parallel form** (intra-chunk attention-like
quadratic + inter-chunk recurrent state), sequence-linear overall — this is
what makes train_4k tractable and long_500k decode O(1) state.  The
sequential form (``mlstm_sequential``) is kept as the oracle for tests.

sLSTM — scalar memory with recurrent state mixing (block-diagonal per-head
recurrent matrices); inherently sequential, lowered via ``lax.scan``.

Block wiring follows the paper's residual blocks: mLSTM block = up-proj x2
(inner, gate) -> causal conv -> q/k/v (block-diagonal per head, qk at half
width) -> cell -> per-head groupnorm -> gate -> down-proj.  sLSTM block =
cell -> groupnorm -> gated MLP (pf = 4/3).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import Params

QK_FACTOR = 0.5  # official xLSTM qk_dim_factor


def _dims(cfg: ModelConfig):
    di = int(cfg.proj_factor * cfg.d_model)
    nh = cfg.n_heads
    dhin = di // nh
    dqk = int(dhin * QK_FACTOR)
    return di, nh, dhin, dqk


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_mlstm_block(key: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    di, nh, dhin, dqk = _dims(cfg)
    ks = jax.random.split(key, 8)
    return {
        "w_up": layers.fan_in_init(ks[0], (d, 2 * di), d),
        "conv": layers.trunc_normal(ks[1], (cfg.conv_width, di), 0.02),
        "wq": layers.fan_in_init(ks[2], (nh, dhin, dqk), dhin),
        "wk": layers.fan_in_init(ks[3], (nh, dhin, dqk), dhin),
        "wv": layers.fan_in_init(ks[4], (nh, dhin, dhin), dhin),
        "w_if": layers.fan_in_init(ks[5], (di, 2 * nh), di),
        "b_i": jnp.full((nh,), -10.0, jnp.float32),  # small initial input gate
        "b_f": jnp.full((nh,), 3.0, jnp.float32),  # forget-open init
        "gn_scale": jnp.ones((nh, dhin), jnp.float32),
        "w_down": layers.fan_in_init(ks[6], (nh, dhin, d), di),
    }


def init_slstm_block(key: jax.Array, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    fi = int(4 * d / 3)
    ks = jax.random.split(key, 11)
    p: Params = {"gn_scale": jnp.ones((d,), jnp.float32)}
    for g, kk in zip(("z", "i", "f", "o"), ks[:4]):
        p[f"w_{g}"] = layers.fan_in_init(kk, (d, d), d)
    for g, kk in zip(("z", "i", "f", "o"), ks[4:8]):
        p[f"r_{g}"] = layers.fan_in_init(kk, (nh, dh, dh), dh) * 0.1
    p["b_z"] = jnp.zeros((d,), jnp.float32)
    p["b_i"] = jnp.full((d,), -10.0, jnp.float32)
    p["b_f"] = jnp.full((d,), 3.0, jnp.float32)
    p["b_o"] = jnp.zeros((d,), jnp.float32)
    p["mlp"] = layers.init_mlp(ks[8], d, fi, "swiglu")
    return p


def init_mlstm_state(cfg: ModelConfig, batch: int) -> Params:
    di, nh, dhin, dqk = _dims(cfg)
    return {
        "C": jnp.zeros((batch, nh, dqk, dhin), jnp.float32),
        "n": jnp.zeros((batch, nh, dqk), jnp.float32),
        "m": jnp.full((batch, nh), -jnp.inf, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), jnp.float32),
    }


def init_slstm_state(cfg: ModelConfig, batch: int) -> Params:
    d = cfg.d_model
    return {
        "h": jnp.zeros((batch, d), jnp.float32),
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -jnp.inf, jnp.float32),
    }


# ---------------------------------------------------------------------------
# mLSTM cell — sequential oracle
# ---------------------------------------------------------------------------

def mlstm_sequential(q, k, v, log_i, log_f, state=None):
    """Reference semantics.  q/k: (B,S,H,dqk), v: (B,S,H,dv),
    log_i/log_f: (B,S,H) f32.  Returns (h (B,S,H,dv), state')."""
    b, s, nh, dqk = q.shape
    dv = v.shape[-1]
    if state is None:
        C = jnp.zeros((b, nh, dqk, dv), jnp.float32)
        n = jnp.zeros((b, nh, dqk), jnp.float32)
        m = jnp.full((b, nh), -jnp.inf, jnp.float32)
    else:
        C, n, m = state["C"], state["n"], state["m"]
    qf = q.astype(jnp.float32) * (dqk ** -0.5)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)

    def step(carry, t):
        C, n, m = carry
        li, lf = log_i[:, t], log_f[:, t]
        m_new = jnp.maximum(lf + m, li)
        fp = jnp.exp(lf + m - m_new)
        ip = jnp.exp(li - m_new)
        C = fp[..., None, None] * C + ip[..., None, None] * jnp.einsum(
            "bhk,bhv->bhkv", kf[:, t], vf[:, t]
        )
        n = fp[..., None] * n + ip[..., None] * kf[:, t]
        num = jnp.einsum("bhk,bhkv->bhv", qf[:, t], C)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhk,bhk->bh", qf[:, t], n)), jnp.exp(-m_new)
        )
        h = num / den[..., None]
        return (C, n, m_new), h

    (C, n, m), hs = jax.lax.scan(step, (C, n, m), jnp.arange(s))
    hs = jnp.moveaxis(hs, 0, 1)  # (B,S,H,dv)
    return hs.astype(q.dtype), {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# mLSTM cell — chunkwise parallel (training path)
# ---------------------------------------------------------------------------

def mlstm_chunkwise(q, k, v, log_i, log_f, state=None, chunk: int = 128):
    """Chunkwise-parallel evaluation, identical semantics to
    ``mlstm_sequential`` (up to float assoc.).  Complexity O(S*chunk) time,
    O(S) memory; state carried across chunks in f32."""
    b, s, nh, dqk = q.shape
    dv = v.shape[-1]
    if s % chunk != 0:
        raise ValueError(f"seq {s} % chunk {chunk} != 0")
    nc = s // chunk
    if state is None:
        C0 = jnp.zeros((b, nh, dqk, dv), jnp.float32)
        n0 = jnp.zeros((b, nh, dqk), jnp.float32)
        m0 = jnp.full((b, nh), -jnp.inf, jnp.float32)
    else:
        C0, n0, m0 = state["C"], state["n"], state["m"]

    ch = lambda x: x.reshape(b, nc, chunk, *x.shape[2:]).swapaxes(0, 1)
    qc = ch(q.astype(jnp.float32) * (dqk ** -0.5))  # (NC,B,L,H,dqk)
    kc, vc = ch(k.astype(jnp.float32)), ch(v.astype(jnp.float32))
    lic, lfc = ch(log_i), ch(log_f)  # (NC,B,L,H)

    idx = jnp.arange(chunk)
    tri = idx[:, None] >= idx[None, :]  # causal within chunk (incl diag)

    def chunk_step(carry, xs):
        C, n, m = carry  # (B,H,dqk,dv), (B,H,dqk), (B,H)
        qq, kk, vv, li, lf = xs
        cum = jnp.cumsum(lf, axis=1)  # (B,L,H) inclusive cumsum of log f
        # decay from chunk start to step t INCLUDING f_t: cum[t]
        # intra-chunk log weights: D[t,s] = cum[t] - cum[s] + li[s]  (s <= t)
        Dmat = cum[:, :, None, :] - cum[:, None, :, :] + li[:, None, :, :]
        Dmat = jnp.where(tri[None, :, :, None], Dmat, -jnp.inf)  # (B,L,L,H)
        m_intra = jnp.max(Dmat, axis=2)  # (B,L,H)
        m_inter = cum + m[:, None, :]  # carried-state contribution
        m_t = jnp.maximum(m_inter, m_intra)  # (B,L,H)
        # intra scores
        scores = jnp.einsum("blhk,bshk->blsh", qq, kk)
        w = jnp.exp(Dmat - m_t[:, :, None, :])
        sw = scores * w
        num = jnp.einsum("blsh,bshv->blhv", sw, vv)
        den = jnp.sum(sw, axis=2)  # (B,L,H)
        # inter (carried state)
        g = jnp.exp(m_inter - m_t)  # (B,L,H)
        num = num + g[..., None] * jnp.einsum("blhk,bhkv->blhv", qq, C)
        den = den + g * jnp.einsum("blhk,bhk->blh", qq, n)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # ---- state update to end of chunk --------------------------------
        total = cum[:, -1]  # (B,H) total log decay of the chunk
        # per-step weight into new state: total - cum[s] + li[s]
        wS = total[:, None, :] - cum + li  # (B,L,H)
        m_new = jnp.maximum(total + m, jnp.max(wS, axis=1))
        scale_old = jnp.exp(total + m - m_new)
        wSn = jnp.exp(wS - m_new[:, None, :])
        C = scale_old[..., None, None] * C + jnp.einsum(
            "blh,blhk,blhv->bhkv", wSn, kk, vv
        )
        n = scale_old[..., None] * n + jnp.einsum("blh,blhk->bhk", wSn, kk)
        return (C, n, m_new), h

    (C, n, m), hs = jax.lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    hs = hs.swapaxes(0, 1).reshape(b, s, nh, dv)
    return hs.astype(q.dtype), {"C": C, "n": n, "m": m}


def mlstm_step(q, k, v, log_i, log_f, state):
    """O(1) decode step.  q/k: (B,H,dqk), v: (B,H,dv), gates (B,H)."""
    C, n, m = state["C"], state["n"], state["m"]
    dqk = q.shape[-1]
    qf = q.astype(jnp.float32) * (dqk ** -0.5)
    m_new = jnp.maximum(log_f + m, log_i)
    fp = jnp.exp(log_f + m - m_new)
    ip = jnp.exp(log_i - m_new)
    C = fp[..., None, None] * C + ip[..., None, None] * jnp.einsum(
        "bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32)
    )
    n = fp[..., None] * n + ip[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhk,bhkv->bhv", qf, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n)), jnp.exp(-m_new))
    h = (num / den[..., None]).astype(q.dtype)
    return h, {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def _mlstm_qkv_gates(cfg: ModelConfig, p: Params, u: jax.Array, uc: jax.Array):
    """u (pre-conv), uc (post-conv+silu): (B,S,Di) -> q,k,v,(log_i,log_f)."""
    di, nh, dhin, dqk = _dims(cfg)
    bsz = u.shape[:-1]
    uh = uc.reshape(*bsz, nh, dhin)
    vh = u.reshape(*bsz, nh, dhin)
    q = jnp.einsum("...hi,hik->...hk", uh, p["wq"].astype(u.dtype))
    k = jnp.einsum("...hi,hik->...hk", uh, p["wk"].astype(u.dtype))
    v = jnp.einsum("...hi,hiv->...hv", vh, p["wv"].astype(u.dtype))
    gates = jnp.einsum("...i,ig->...g", uc.astype(jnp.float32), p["w_if"].astype(jnp.float32))
    gi, gf = jnp.split(gates, 2, axis=-1)
    log_i = gi + p["b_i"]
    log_f = jax.nn.log_sigmoid(gf + p["b_f"])
    return q, k, v, log_i, log_f


def _groupnorm_heads(scale: jax.Array, h: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS groupnorm.  h: (..., H, dv)."""
    hf = h.astype(jnp.float32)
    hf = hf * jax.lax.rsqrt(jnp.mean(hf * hf, axis=-1, keepdims=True) + eps)
    return (hf * scale).astype(h.dtype)


def mlstm_block_train(
    cfg: ModelConfig, p: Params, x: jax.Array, state: Optional[Params] = None
) -> tuple[jax.Array, Params]:
    """x: (B,S,D) -> (out, state')."""
    from repro.models.rglru import _causal_conv

    dt = x.dtype
    up = jnp.einsum("bsd,du->bsu", x, p["w_up"].astype(dt))
    u, z = jnp.split(up, 2, axis=-1)
    prefix = state["conv"] if state is not None else None
    uc, conv_state = _causal_conv({"conv": p["conv"]}, u, prefix)
    uc = jax.nn.silu(uc)
    q, k, v, log_i, log_f = _mlstm_qkv_gates(cfg, p, u, uc)
    cell_state = None
    if state is not None:
        cell_state = {"C": state["C"], "n": state["n"], "m": state["m"]}
    h, new_cell = mlstm_chunkwise(
        q, k, v, log_i, log_f, cell_state, chunk=min(cfg.mlstm_chunk, x.shape[1])
    )
    h = _groupnorm_heads(p["gn_scale"], h)
    di, nh, dhin, _ = _dims(cfg)
    zh = jax.nn.silu(z).reshape(*z.shape[:-1], nh, dhin)
    out = jnp.einsum("bshv,hvd->bsd", h * zh, p["w_down"].astype(dt))
    new_state = dict(new_cell, conv=conv_state.astype(jnp.float32))
    return out, new_state


def mlstm_block_step(
    cfg: ModelConfig, p: Params, x: jax.Array, state: Params
) -> tuple[jax.Array, Params]:
    """x: (B,1,D) decode step."""
    dt = x.dtype
    xs = x[:, 0]
    up = xs @ p["w_up"].astype(dt)
    u, z = jnp.split(up, 2, axis=-1)
    hist = jnp.concatenate([state["conv"].astype(dt), u[:, None]], axis=1)
    uc = jax.nn.silu(jnp.einsum("bcw,cw->bw", hist, p["conv"].astype(dt)))
    q, k, v, log_i, log_f = _mlstm_qkv_gates(cfg, p, u, uc)
    h, new_cell = mlstm_step(
        q, k, v, log_i, log_f, {"C": state["C"], "n": state["n"], "m": state["m"]}
    )
    h = _groupnorm_heads(p["gn_scale"], h)
    di, nh, dhin, _ = _dims(cfg)
    zh = jax.nn.silu(z).reshape(-1, nh, dhin)
    out = jnp.einsum("bhv,hvd->bd", h * zh, p["w_down"].astype(dt))
    new_state = dict(new_cell, conv=hist[:, 1:].astype(jnp.float32))
    return out[:, None], new_state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def _slstm_cell_step(cfg: ModelConfig, p: Params, xt: jax.Array, st: Params):
    """One sLSTM step.  xt: (B, D) f32 gate pre-activations computed here."""
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    hprev = st["h"].reshape(-1, nh, dh)

    def gate(name):
        wx = xt @ p[f"w_{name}"].astype(jnp.float32)
        rh = jnp.einsum("bhi,hij->bhj", hprev, p[f"r_{name}"].astype(jnp.float32))
        return wx + rh.reshape(-1, d) + p[f"b_{name}"]

    z = jnp.tanh(gate("z"))
    li = gate("i")  # log input gate (exp gating)
    lf = jax.nn.log_sigmoid(gate("f"))
    o = jax.nn.sigmoid(gate("o"))
    m_new = jnp.maximum(lf + st["m"], li)
    fp = jnp.exp(lf + st["m"] - m_new)
    ip = jnp.exp(li - m_new)
    c = fp * st["c"] + ip * z
    n = fp * st["n"] + ip
    h = o * c / jnp.maximum(n, 1e-6)
    return {"h": h, "c": c, "n": n, "m": m_new}


def slstm_block_train(
    cfg: ModelConfig, p: Params, x: jax.Array, state: Optional[Params] = None
) -> tuple[jax.Array, Params]:
    b, s, d = x.shape
    st = state
    if st is None:
        st = init_slstm_state(cfg, b)
    cell = {k: st[k] for k in ("h", "c", "n", "m")}

    def step(carry, xt):
        new = _slstm_cell_step(cfg, p, xt, carry)
        return new, new["h"]

    cell, hs = jax.lax.scan(step, cell, x.astype(jnp.float32).swapaxes(0, 1))
    hs = hs.swapaxes(0, 1)  # (B,S,D)
    nh = cfg.n_heads
    dh = d // nh
    hn = _groupnorm_heads(
        p["gn_scale"].reshape(nh, dh), hs.reshape(b, s, nh, dh)
    ).reshape(b, s, d).astype(x.dtype)
    out = layers.mlp_apply(p["mlp"], hn, "swiglu")
    return out, cell


def slstm_block_step(
    cfg: ModelConfig, p: Params, x: jax.Array, state: Params
) -> tuple[jax.Array, Params]:
    b = x.shape[0]
    d = cfg.d_model
    cell = {k: state[k] for k in ("h", "c", "n", "m")}
    new = _slstm_cell_step(cfg, p, x[:, 0].astype(jnp.float32), cell)
    nh = cfg.n_heads
    dh = d // nh
    hn = _groupnorm_heads(
        p["gn_scale"].reshape(nh, dh), new["h"].reshape(b, nh, dh)
    ).reshape(b, d).astype(x.dtype)
    out = layers.mlp_apply(p["mlp"], hn, "swiglu")
    return out[:, None], new

"""Shared neural blocks: norms, MLPs, embeddings.

Pure-functional: ``init_*`` returns a param pytree, ``*_apply`` is pure.
Compute dtype is bf16 (cast at block entry), accumulation/normalization f32.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

Params = dict


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def trunc_normal(key: jax.Array, shape, scale: float = 0.02, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale).astype(dtype)


def fan_in_init(key: jax.Array, shape, fan_in: Optional[int] = None, dtype=jnp.float32):
    fi = fan_in if fan_in is not None else shape[0]
    return trunc_normal(key, shape, scale=fi ** -0.5, dtype=dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(key: jax.Array, d: int, norm_type: str) -> Params:
    if norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    if norm_type == "layernorm_nonparam":  # OLMo: non-parametric LN
        return {}
    raise ValueError(f"unknown norm_type {norm_type!r}")


def norm_apply(p: Params, x: jax.Array, norm_type: str, eps: float = 1e-6) -> jax.Array:
    """Norm with f32 *statistics* but bf16 elementwise math.

    Keeping the full-width tensor in compute dtype matters under
    scan+remat: a full f32 upcast of x gets hoisted by XLA into the forward
    loop and saved per layer (measured: a stacked (L,B,S,D) f32 residual =
    12 GiB for 8 internlm2 layers).  f32 statistics preserve the numerics
    that matter (mean/variance accumulation); the (B,S,1) stats are tiny.
    """
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(ms + eps).astype(x.dtype)
        return y * p["scale"].astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True) - mu * mu
    y = (x - mu.astype(x.dtype)) * jax.lax.rsqrt(
        jnp.maximum(var, 0.0) + eps
    ).astype(x.dtype)
    if norm_type == "layernorm":
        y = y * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)
    return y


def init_rms_head_norm(key: jax.Array, head_dim: int) -> Params:
    """Per-head-dim RMSNorm for qk-norm (Qwen3)."""
    return {"scale": jnp.ones((head_dim,), jnp.float32)}


def head_norm_apply(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (xf * p["scale"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

GATED = {"swiglu", "geglu"}


def init_mlp(key: jax.Array, d: int, f: int, mlp_type: str, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Params = {"wi": fan_in_init(k1, (d, f), d, dtype), "wo": fan_in_init(k2, (f, d), f, dtype)}
    if mlp_type in GATED:
        p["wg"] = fan_in_init(k3, (d, f), d, dtype)
    return p


def _act(h: jax.Array, mlp_type: str) -> jax.Array:
    if mlp_type in ("swiglu",):
        return jax.nn.silu(h)
    if mlp_type in ("geglu", "gelu"):
        return jax.nn.gelu(h)
    if mlp_type == "relu2":  # Nemotron/Minitron squared ReLU
        r = jax.nn.relu(h)
        return r * r
    raise ValueError(f"unknown mlp_type {mlp_type!r}")


def mlp_apply(p: Params, x: jax.Array, mlp_type: str) -> jax.Array:
    h = jnp.einsum("...d,df->...f", x, p["wi"].astype(x.dtype))
    h = _act(h, mlp_type)
    if mlp_type in GATED:
        h = h * jnp.einsum("...d,df->...f", x, p["wg"].astype(x.dtype))
    return jnp.einsum("...f,fd->...d", h, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# embeddings / output head
# ---------------------------------------------------------------------------

def init_embed(key: jax.Array, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"tok": trunc_normal(key, (vocab, d), 0.02, dtype)}


def embed_apply(p: Params, tokens: jax.Array, compute_dtype=jnp.bfloat16) -> jax.Array:
    return p["tok"].astype(compute_dtype)[tokens]


def init_head(key: jax.Array, d: int, vocab: int, dtype=jnp.float32) -> Params:
    return {"out": fan_in_init(key, (d, v := vocab), d, dtype)}


def head_apply(p: Params, x: jax.Array) -> jax.Array:
    return jnp.einsum("...d,dv->...v", x, p["out"].astype(x.dtype))

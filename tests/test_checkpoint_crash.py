"""Checkpoint commit-sequence kill-point tests (ISSUE 5 satellite).

The commit sequence in ``checkpoint/manager.py`` is::

    write leaves+meta into step_X.tmp   (fsync'd)
    [overwrite] step_X -> step_X.old    (move the previous copy aside)
    step_X.tmp -> step_X                (the atomic commit rename)
    fsync(dir); delete step_X.old

A crash at ANY point must leave a loadable step behind, and a fresh
``CheckpointManager`` (the restart) must recover the directory: stale
``.tmp`` dirs are partial by construction and are swept; an orphaned
``.old`` is the only surviving copy of its step exactly when the crash hit
before the commit rename, and is recovered as the step.

Two mechanisms: manufactured on-disk crash states (true process-death
semantics — no rollback code ran) and injected exceptions (the in-process
failure paths: rollback on a failed commit rename, a failed leaf write).
"""
import shutil
from pathlib import Path

import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(tag: float):
    return {"w": np.full((4, 3), tag, np.float32), "b": np.arange(5, dtype=np.int32) + int(tag)}


def _template():
    return _tree(0.0)


def _commit(directory, step, tag, keep=3):
    mgr = CheckpointManager(directory, keep=keep)
    mgr.save(step, _tree(tag), blocking=True)
    return mgr


def _value(tree) -> float:
    return float(tree["w"][0, 0])


def _make_committed_dir(tmp_path, name, step, tag):
    """A fully-committed step_XXXX dir with ``tag`` contents, detached from
    any manager (raw material for manufacturing crash states)."""
    scratch = tmp_path / f"scratch-{name}"
    _commit(scratch, step, tag)
    return scratch / f"step_{step:08d}"


# ---------------------------------------------------------------------------
# manufactured crash states (process died, no in-process cleanup ran)
# ---------------------------------------------------------------------------


def test_crash_mid_tmp_write_recovers_previous_step(tmp_path):
    """Kill point: mid leaf write — a partial .tmp with no meta.json."""
    d = tmp_path / "ckpt"
    _commit(d, 0, 1.0)
    tmp = d / "step_00000001.tmp"
    tmp.mkdir()
    with open(tmp / "w.npy", "wb") as f:
        f.write(b"\x93NUMPY partial garbage")
    mgr = CheckpointManager(d)  # the restart
    assert not tmp.exists()  # partial tmp swept
    step, tree = mgr.restore(_template())
    assert step == 0 and _value(tree) == 1.0


def test_crash_after_tmp_fully_written_before_commit(tmp_path):
    """Kill point: after the tmp write, before any rename.  The tmp is
    complete but uncommitted — it must still be treated as partial (the
    commit rename is the durability point) and swept."""
    d = tmp_path / "ckpt"
    _commit(d, 0, 1.0)
    full = _make_committed_dir(tmp_path, "a", 1, 2.0)
    shutil.copytree(full, d / "step_00000001.tmp")
    mgr = CheckpointManager(d)
    assert not (d / "step_00000001.tmp").exists()
    step, tree = mgr.restore(_template())
    assert step == 0 and _value(tree) == 1.0


def test_crash_after_move_aside_before_commit_recovers_old(tmp_path):
    """Kill point: overwrite of step 0 crashed between ``final -> .old``
    and ``tmp -> final``: the .old is the ONLY copy of the step and must be
    recovered (the tmp is swept)."""
    d = tmp_path / "ckpt"
    _commit(d, 0, 1.0)
    final = d / "step_00000000"
    final.rename(d / "step_00000000.old")
    new = _make_committed_dir(tmp_path, "b", 0, 2.0)
    shutil.copytree(new, d / "step_00000000.tmp")
    mgr = CheckpointManager(d)
    assert not (d / "step_00000000.tmp").exists()
    assert not (d / "step_00000000.old").exists()
    step, tree = mgr.restore(_template())
    assert step == 0 and _value(tree) == 1.0  # the old copy survived


def test_crash_after_commit_before_old_delete_keeps_new(tmp_path):
    """Kill point: after the commit rename, before the .old delete: the
    new copy is committed — restore must see it, and the stale .old must
    be dropped (not resurrected over the newer commit)."""
    d = tmp_path / "ckpt"
    _commit(d, 0, 2.0)  # the NEW committed copy
    old = _make_committed_dir(tmp_path, "c", 0, 1.0)
    shutil.copytree(old, d / "step_00000000.old")
    mgr = CheckpointManager(d)
    assert not (d / "step_00000000.old").exists()
    step, tree = mgr.restore(_template())
    assert step == 0 and _value(tree) == 2.0  # the commit won


def test_crash_before_first_commit_leaves_no_steps(tmp_path):
    d = tmp_path / "ckpt"
    d.mkdir()
    (d / "step_00000000.tmp").mkdir()
    mgr = CheckpointManager(d)
    assert mgr.latest_step() is None
    with pytest.raises(FileNotFoundError):
        mgr.restore(_template())


# ---------------------------------------------------------------------------
# injected exceptions (the in-process failure paths)
# ---------------------------------------------------------------------------


def test_failed_leaf_write_keeps_previous_step(tmp_path, monkeypatch):
    """np.save raising mid-write surfaces on the (blocking) save, leaves
    the previous commit loadable, and the next init sweeps the tmp."""
    d = tmp_path / "ckpt"
    mgr = _commit(d, 0, 1.0)
    real_save = np.save
    calls = {"n": 0}

    def flaky_save(f, arr, *a, **kw):
        calls["n"] += 1
        if calls["n"] == 2:  # second leaf of the new checkpoint
            raise RuntimeError("injected leaf-write fault")
        return real_save(f, arr, *a, **kw)

    monkeypatch.setattr(np, "save", flaky_save)
    with pytest.raises(RuntimeError, match="injected leaf-write"):
        mgr.save(1, _tree(2.0), blocking=True)
    monkeypatch.setattr(np, "save", real_save)
    mgr2 = CheckpointManager(d)
    assert not (d / "step_00000001.tmp").exists()
    step, tree = mgr2.restore(_template())
    assert step == 0 and _value(tree) == 1.0


def test_failed_commit_rename_rolls_back_old_copy(tmp_path, monkeypatch):
    """A commit rename that raises must roll the moved-aside previous copy
    back into place — the step stays loadable with its OLD contents and no
    .old orphan remains."""
    d = tmp_path / "ckpt"
    mgr = _commit(d, 0, 1.0)
    real_rename = Path.rename

    def flaky_rename(self, target):
        if str(self).endswith(".tmp") and not str(target).endswith(".old"):
            raise OSError("injected commit-rename fault")
        return real_rename(self, target)

    monkeypatch.setattr(Path, "rename", flaky_rename)
    with pytest.raises(OSError, match="injected commit-rename"):
        mgr.save(0, _tree(2.0), blocking=True)  # overwrite of step 0
    monkeypatch.setattr(Path, "rename", real_rename)
    assert not (d / "step_00000000.old").exists()
    step, tree = mgr.restore(_template())
    assert step == 0 and _value(tree) == 1.0  # rolled back to the old copy
    # and the manager is still serviceable: a clean overwrite commits
    mgr.save(0, _tree(3.0), blocking=True)
    step, tree = mgr.restore(_template())
    assert step == 0 and _value(tree) == 3.0


def test_every_kill_point_always_leaves_a_loadable_step(tmp_path):
    """Sweep: for each kill point of an overwrite save of step 1 (with a
    committed step 0 behind it), a restart must find SOME loadable step,
    and step 0 must never be the casualty of step 1's crash."""
    kill_states = {
        "partial_tmp": lambda d: (d / "step_00000001.tmp").mkdir(),
        "full_tmp": lambda d: shutil.copytree(
            _make_committed_dir(d.parent, "k1", 1, 9.0), d / "step_00000001.tmp"
        ),
        "old_moved_no_commit": lambda d: (
            (d / "step_00000001").rename(d / "step_00000001.old"),
            shutil.copytree(
                _make_committed_dir(d.parent, "k2", 1, 9.0),
                d / "step_00000001.tmp",
            ),
        ),
        "committed_old_undeleted": lambda d: shutil.copytree(
            _make_committed_dir(d.parent, "k3", 1, 8.0), d / "step_00000001.old"
        ),
    }
    for name, make_state in kill_states.items():
        d = tmp_path / f"ckpt-{name}"
        _commit(d, 0, 1.0)
        if name in ("old_moved_no_commit", "committed_old_undeleted"):
            _commit(d, 1, 2.0)
        make_state(d)
        mgr = CheckpointManager(d)
        steps = mgr.all_steps()
        assert 0 in steps, (name, steps)
        step, tree = mgr.restore(_template(), step=0)
        assert _value(tree) == 1.0, name
        latest = mgr.latest_step()
        _, latest_tree = mgr.restore(_template(), step=latest)
        assert np.isfinite(_value(latest_tree)), name
        assert not list(d.glob("*.tmp")) and not list(d.glob("*.old")), name

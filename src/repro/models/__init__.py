"""Model substrate: every assigned architecture family, pure functional JAX."""
from repro.models import attention, frontends, layers, moe, rglru, rope, transformer, xlstm
from repro.models.transformer import (
    decode_step,
    forward_train,
    init_caches,
    init_model,
    lm_loss,
    prefill,
)

__all__ = [
    "attention",
    "frontends",
    "layers",
    "moe",
    "rglru",
    "rope",
    "transformer",
    "xlstm",
    "init_model",
    "init_caches",
    "forward_train",
    "prefill",
    "decode_step",
    "lm_loss",
]

"""Core offload abstraction tests: memory kinds, refs, streaming engines.

Includes hypothesis property tests on the system invariants:
  * streaming schedule never changes values (paper §3.1),
  * every (buffer_size, elems_per_fetch, distance) is either valid or
    raises at construction,
  * kind placement round-trips.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import given, settings, strategies as hst

from repro import jaxcompat
from repro.core import memkind as mk
from repro.core.hoststream import HostStreamExecutor, StreamStats
from repro.core.offload import offload
from repro.core.prefetch import streamed_scan, stream_blocks
from repro.core.refspec import Access, OffloadRef, PrefetchSpec


# ---------------------------------------------------------------------------
# memory kinds
# ---------------------------------------------------------------------------

def test_backend_enumerates_kinds():
    kinds = mk.backend_memory_kinds()
    assert kinds  # every backend exposes at least its default tier
    default = mk.default_memory_kind()
    assert default is None or default in kinds


def test_kind_resolution_fallback_only_for_host():
    assert mk.resolve_kind("device") == mk.DEVICE
    k = mk.resolve_kind("pinned_host")
    assert k.jax_kind in ("pinned_host", "device")


def test_sharding_for_every_kind_is_constructible():
    """Logical kinds must map onto *some* tier on every backend."""
    mesh = jaxcompat.make_mesh((1,), ("data",))
    for kind in (mk.DEVICE, mk.PINNED_HOST, mk.UNPINNED_HOST):
        s = mk.sharding_for(mesh, jax.sharding.PartitionSpec(), kind)
        y = jax.device_put(jnp.arange(4.0), s)
        np.testing.assert_array_equal(np.asarray(y), np.arange(4.0))


def test_place_round_trip():
    mesh = jaxcompat.make_mesh((1,), ("data",))
    x = jnp.arange(16.0)
    y = mk.place(x, mesh, jax.sharding.PartitionSpec(), mk.DEVICE)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_policy_one_line_change():
    """The paper's 'swap the kind' property: a policy change is one field."""
    pol = mk.ALL_DEVICE
    moved = pol.with_(opt_state=mk.PINNED_HOST)
    assert moved.opt_state.jax_kind == "pinned_host"
    assert moved.params == pol.params
    assert moved.requires_host()
    assert not pol.requires_host()


def test_new_kind_is_a_subclass():
    """Paper §3.2: a new hierarchy level is a new Kind subclass."""

    class RemotePool(mk.MemKind):
        jax_kind = "pinned_host"  # transport; logically a new level
        level = 9
        directly_addressable = False

    k = RemotePool()
    assert k.level == 9 and not k.directly_addressable


# ---------------------------------------------------------------------------
# PrefetchSpec validation (property)
# ---------------------------------------------------------------------------

@given(
    buffer_size=hst.integers(-2, 8),
    elems=hst.integers(-2, 8),
    distance=hst.integers(-2, 8),
)
def test_prefetch_spec_valid_or_raises(buffer_size, elems, distance):
    valid = (
        buffer_size >= 1
        and elems >= 1
        and 0 <= distance < buffer_size + elems
    )
    if valid:
        s = PrefetchSpec(buffer_size, elems, distance)
        assert s.on_demand == (distance == 0)
    else:
        with pytest.raises(ValueError):
            PrefetchSpec(buffer_size, elems, distance)


# ---------------------------------------------------------------------------
# streamed_scan: schedule-invariance property
# ---------------------------------------------------------------------------

def _layer_body(carry, p):
    return jnp.tanh(carry @ p["w"] + p["b"]), None


@settings(max_examples=20, deadline=None)
@given(
    distance=hst.integers(0, 3),
    elems=hst.sampled_from([1, 2, 4]),
)
def test_streamed_scan_schedule_invariance(distance, elems):
    L, d = 8, 4
    key = jax.random.PRNGKey(0)
    stacked = {
        "w": jax.random.normal(key, (L, d, d)) * 0.5,
        "b": jnp.zeros((L, d)),
    }
    x0 = jnp.ones((2, d))
    spec = PrefetchSpec(buffer_size=max(distance + 1, 1), elements_per_fetch=elems,
                        distance=distance)
    ref, _ = jax.lax.scan(_layer_body, x0, stacked)
    out, _ = streamed_scan(_layer_body, x0, stacked, prefetch=spec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_stream_blocks_elementwise():
    xs = jnp.arange(64.0).reshape(16, 4)
    ys = jnp.ones((16, 4))
    spec = PrefetchSpec(buffer_size=2, elements_per_fetch=4, distance=1)
    out = stream_blocks(lambda a, b: a + b, (xs, ys), prefetch=spec)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(xs + ys))


# ---------------------------------------------------------------------------
# @offload decorator (paper Listings 1-3)
# ---------------------------------------------------------------------------

def test_offload_listing1_semantics():
    @offload
    def mykernel(a, b):
        return a + b

    a = np.arange(1000.0, dtype=np.float32)
    b = np.ones(1000, dtype=np.float32)
    out = mykernel(a, b)
    np.testing.assert_array_equal(np.asarray(out), a + b)


def test_offload_eager_equals_streamed():
    refs = dict(
        a=OffloadRef(kind=mk.PINNED_HOST,
                     prefetch=PrefetchSpec(buffer_size=4, elements_per_fetch=2, distance=2)),
        b=OffloadRef(kind=mk.PINNED_HOST,
                     prefetch=PrefetchSpec(buffer_size=4, elements_per_fetch=2, distance=2)),
    )

    @offload(refs=refs)
    def mykernel(a, b):
        return a * 2.0 + b

    a = np.random.randn(16, 8).astype(np.float32)
    b = np.random.randn(16, 8).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(mykernel(a, b)), np.asarray(mykernel.eager(a, b)), rtol=1e-6
    )


def test_offload_place_device_resident():
    """Paper's define_on_device/copy_to_device: pre-place then reuse."""

    @offload
    def k(a, b):
        return a + b

    a_dev = k.place("a", np.ones(8, np.float32))
    out = k(a_dev, np.ones(8, np.float32))
    np.testing.assert_array_equal(np.asarray(out), np.full(8, 2.0, np.float32))


def test_offload_ref_rejects_device_prefetch():
    with pytest.raises(ValueError):
        OffloadRef(kind=mk.DEVICE, prefetch=PrefetchSpec())


# ---------------------------------------------------------------------------
# host-stream executor: request accounting (paper Table 2's real story)
# ---------------------------------------------------------------------------

def test_hoststream_modes_same_result_different_schedule():
    @jax.jit
    def apply(carry, g):
        return carry + jnp.sum(g)

    groups = [np.full((4, 4), float(i), np.float32) for i in range(6)]
    results = {}
    stats = {}
    for mode in ("eager", "on_demand", "prefetch"):
        ex = HostStreamExecutor(apply)
        st = StreamStats()
        out, _ = ex.run(jnp.zeros(()), groups, mode=mode,
                        prefetch=PrefetchSpec(buffer_size=3, elements_per_fetch=1, distance=2),
                        stats=st)
        results[mode] = float(out)
        stats[mode] = st
    assert len(set(results.values())) == 1  # identical values
    assert all(stats[m].n_transfers == 6 for m in stats)
    assert stats["prefetch"].bytes_h2d == stats["on_demand"].bytes_h2d


def test_hoststream_writeback_rw_access():
    """Paper's 'rw' access modifier: written groups return to the host."""
    @jax.jit
    def apply(carry, g):
        return carry, g * 2.0

    groups = [np.ones((2, 2), np.float32) * i for i in range(4)]
    ex = HostStreamExecutor(apply, writeback=True)
    _, outs = ex.run(jnp.zeros(()), groups, mode="prefetch",
                     prefetch=PrefetchSpec(buffer_size=2, elements_per_fetch=1, distance=1))
    assert len(outs) == 4
    np.testing.assert_array_equal(outs[3], np.full((2, 2), 6.0, np.float32))

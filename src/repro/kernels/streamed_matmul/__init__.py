from repro.kernels.streamed_matmul.ops import streamed_matmul
from repro.kernels.streamed_matmul.ref import matmul_ref

__all__ = ["streamed_matmul", "matmul_ref"]

"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)            # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            # input gate
    a_t = exp(-c * softplus(Lambda) * r_t)  # c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training lowers the linear recurrence through ``jax.lax.associative_scan``
(O(log S) depth); decode is the O(1) sequential update — which is what makes
long_500k tractable for this family.  A Pallas chunked-scan kernel for the
training path lives in ``repro.kernels.rglru_scan``.

Block structure (Griffin): pre-norm -> {gate branch: linear+GeLU} x
{recurrent branch: linear -> causal conv(4) -> RG-LRU} -> out proj.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers
from repro.models.layers import Params

C_RGLRU = 8.0


def init_rglru_block(key: jax.Array, cfg: ModelConfig) -> Params:
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 7)
    # Lambda init so a = exp(-c*softplus(L)) is in ~(0.9, 0.999) (paper app. A)
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / C_RGLRU))  # softplus^-1(-log u / c)
    return {
        "w_in": layers.fan_in_init(ks[1], (d, w), d),
        "w_gate": layers.fan_in_init(ks[2], (d, w), d),
        "conv": layers.trunc_normal(ks[3], (cfg.conv_width, w), 0.02),
        "w_a": layers.fan_in_init(ks[4], (w, w), w),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_x": layers.fan_in_init(ks[5], (w, w), w),
        "b_x": jnp.zeros((w,), jnp.float32),
        "lambda": lam,
        "w_out": layers.fan_in_init(ks[6], (w, d), w),
    }


def init_rglru_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> Params:
    w = cfg.lru_width
    return {
        "h": jnp.zeros((batch, w), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
    }


def _gates(p: Params, x: jax.Array):
    """x: (..., W) -> (a, b) of the affine recurrence h = a*h + b, in f32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_x"].astype(jnp.float32) + p["b_x"])
    log_a = -C_RGLRU * jax.nn.softplus(p["lambda"]) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via expm1: 1-a^2 = -expm1(2 log a)
    b = jnp.sqrt(-jnp.expm1(2.0 * log_a)) * (i * xf)
    return a, b


def rglru_scan(p: Params, x: jax.Array, h0: Optional[jax.Array] = None):
    """Associative scan over the sequence.  x: (B, S, W) -> (y, h_last)."""
    a, b = _gates(p, x)  # (B, S, W) f32
    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(p: Params, x: jax.Array, h: jax.Array):
    """One decode step.  x: (B, W), h: (B, W) -> (y, h')."""
    a, b = _gates(p, x[:, None, :])
    hf = a[:, 0] * h.astype(jnp.float32) + b[:, 0]
    return hf.astype(x.dtype), hf


def _causal_conv(p: Params, x: jax.Array, prefix: Optional[jax.Array] = None):
    """Depthwise causal conv, width cw.  x: (B, S, W); prefix: (B, cw-1, W)."""
    cw = p["conv"].shape[0]
    if prefix is None:
        prefix = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    for j in range(cw):
        out = out + xp[:, j : j + x.shape[1]] * p["conv"][j].astype(x.dtype)
    return out, xp[:, -(cw - 1) :] if cw > 1 else prefix


def rglru_block_train(
    cfg: ModelConfig, p: Params, x: jax.Array, state: Optional[Params] = None,
    use_kernel: bool = False,
) -> tuple[jax.Array, Params]:
    """Full-sequence application.  x: (B, S, D) -> (out, new_state)."""
    dt = x.dtype
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_gate"].astype(dt)))
    u = jnp.einsum("bsd,dw->bsw", x, p["w_in"].astype(dt))
    prefix = state["conv"] if state is not None else None
    u, conv_state = _causal_conv(p, u, prefix)
    h0 = state["h"] if state is not None else None
    if use_kernel:
        from repro.kernels.rglru_scan import ops as lru_ops

        a, b = _gates(p, u)
        if h0 is not None:
            b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
        h = lru_ops.linear_recurrence(a, b)
        y, h_last = h.astype(dt), h[:, -1]
    else:
        y, h_last = rglru_scan(p, u, h0)
    out = jnp.einsum("bsw,wd->bsd", y * gate, p["w_out"].astype(dt))
    new_state = {"h": h_last, "conv": conv_state.astype(jnp.float32)}
    return out, new_state


def rglru_block_step(
    cfg: ModelConfig, p: Params, x: jax.Array, state: Params
) -> tuple[jax.Array, Params]:
    """One decode step.  x: (B, 1, D) -> (out (B,1,D), new_state)."""
    dt = x.dtype
    xs = x[:, 0]
    gate = jax.nn.gelu(xs @ p["w_gate"].astype(dt))
    u = xs @ p["w_in"].astype(dt)
    # conv over the stored prefix + current input
    cw = cfg.conv_width
    hist = jnp.concatenate([state["conv"].astype(dt), u[:, None]], axis=1)  # (B, cw, W)
    u_conv = jnp.einsum("bcw,cw->bw", hist, p["conv"].astype(dt))
    y, h = rglru_step(p, u_conv, state["h"])
    out = (y * gate) @ p["w_out"].astype(dt)
    new_state = {"h": h, "conv": hist[:, 1:].astype(jnp.float32)}
    return out[:, None], new_state

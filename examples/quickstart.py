"""Quickstart: the paper's offload abstractions in five minutes.

Run:  PYTHONPATH=src:. python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (
    HOST_OPT,
    OffloadRef,
    PrefetchSpec,
    memkind as mk,
    offload,
)

# ---------------------------------------------------------------------------
# 1. Paper Listing 1: decorate a kernel; arguments are passed BY REFERENCE
# ---------------------------------------------------------------------------
nums1 = np.random.randint(0, 100, 1000).astype(np.float32)
nums2 = np.random.randint(0, 100, 1000).astype(np.float32)


@offload
def mykernel(a, b):
    return a + b


print("listing-1 sum:", np.asarray(mykernel(nums1, nums2))[:5], "...")

# ---------------------------------------------------------------------------
# 2. Paper Listing 2: add a prefetch annotation — same result, streamed
#    through a bounded device buffer (buffer_size / elements_per_fetch /
#    distance are the paper's exact knobs)
# ---------------------------------------------------------------------------
spec = PrefetchSpec(buffer_size=10, elements_per_fetch=2, distance=4)


@offload(refs=dict(
    a=OffloadRef(kind=mk.PINNED_HOST, prefetch=spec),
    b=OffloadRef(kind=mk.PINNED_HOST, prefetch=spec),
))
def mykernel2(a, b):
    return a + b


big_a = np.random.randn(64, 1024).astype(np.float32)  # lives at the Host kind
big_b = np.random.randn(64, 1024).astype(np.float32)
out = mykernel2(big_a, big_b)
print("listing-2 streamed:", np.allclose(np.asarray(out), big_a + big_b))

# ---------------------------------------------------------------------------
# 3. Paper Listing 3 / §3.2: memory kinds — one line moves data between
#    hierarchy levels; the kind handles the mechanics
# ---------------------------------------------------------------------------
from repro.jaxcompat import make_mesh

mesh = make_mesh((1,), ("data",))
x = jnp.arange(8.0)
x_host = mk.place(x, mesh, jax.sharding.PartitionSpec(), mk.PINNED_HOST)
x_dev = mk.place(x_host, mesh, jax.sharding.PartitionSpec(), mk.DEVICE)
print("kind round-trip:", np.allclose(np.asarray(x_dev), np.asarray(x)),
      f"(backend host-offload support: {mk.host_offload_supported()})")

# placement policies: the production form of the same idea
print("policy:", HOST_OPT.name, "-> optimizer state lives at",
      HOST_OPT.opt_state.jax_kind)

# ---------------------------------------------------------------------------
# 4. The TPU-native kernel level: weights stay in HBM, prefetched to VMEM
# ---------------------------------------------------------------------------
from repro.kernels.streamed_matmul import streamed_matmul, matmul_ref

xk = jax.random.normal(jax.random.PRNGKey(0), (256, 512), jnp.float32)
wk = jax.random.normal(jax.random.PRNGKey(1), (512, 256), jnp.float32)
y = streamed_matmul(xk, wk, spec=PrefetchSpec(buffer_size=3, elements_per_fetch=1, distance=2))
print("streamed matmul matches oracle:",
      np.allclose(np.asarray(y), np.asarray(matmul_ref(xk, wk)), atol=1e-3))

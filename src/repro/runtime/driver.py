"""Fault-tolerant training driver: restart loop + watchdog + checkpointing.

The driver owns everything a pod-scale job needs around the compiled step:

  * periodic async checkpoints (atomic, keep-k),
  * a restart loop: any step exception (device failure surfaces as one) or
    watchdog deadline restores the latest checkpoint and continues —
    `max_restarts` bounds flapping,
  * straggler monitoring (robust z-score on step times),
  * stateless data: batch(step) is a pure function, so restarts replay
    identical data (bit-identical loss curves across failures — tested),
  * failure injection hooks for testing (``fail_at`` raises mid-run),
  * transfer-engine lifecycle for the streamed-optimizer path: the driver
    owns the ``TransferEngine`` passed to it, logs its per-run stream stats
    (including per-tier disk counters), and closes it when the run
    completes (or finally fails) — followed by the ``DiskHost`` spill
    store, so no in-flight disk fetch outlives its chunk files.

On a real cluster the restart loop wraps `jax.distributed` re-initialization
and an elastic re-mesh (repro.runtime.elastic); on this container the same
code path is exercised single-process.
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Optional

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.runtime.straggler import StragglerMonitor

log = logging.getLogger("repro.driver")

Pytree = Any


@dataclasses.dataclass
class DriverConfig:
    total_steps: int
    checkpoint_every: int = 100
    checkpoint_dir: str = "checkpoints"
    keep: int = 3
    max_restarts: int = 3
    step_deadline_s: Optional[float] = None
    log_every: int = 10


class TrainDriver:
    """Runs ``state = step_fn(state, batch(step))`` with fault tolerance.

    ``state`` is any pytree (params+opt); ``step_fn`` returns
    ``(state, metrics)``.
    """

    def __init__(
        self,
        cfg: DriverConfig,
        step_fn: Callable[[Pytree, Pytree], tuple[Pytree, dict]],
        make_batch: Callable[[int], Pytree],
        init_state: Callable[[], Pytree],
        *,
        fail_at: Optional[set[int]] = None,  # test hook: raise at these steps
        engine: Optional[Any] = None,  # repro.core.engine.TransferEngine
        stream_stats: Optional[Any] = None,  # repro.core.hoststream.StreamStats
        spill_store: Optional[Any] = None,  # repro.core.spillstore.SpillStore
        run_meta: Optional[dict] = None,  # mesh fingerprint etc. → checkpoint
        on_restart: Optional[Callable[[int], None]] = None,  # restart hook
    ) -> None:
        self.cfg = cfg
        self.step_fn = step_fn
        self.make_batch = make_batch
        self.init_state = init_state
        self.fail_at = set(fail_at or ())
        self.ckpt = CheckpointManager(cfg.checkpoint_dir, keep=cfg.keep)
        self.monitor = StragglerMonitor(
            deadline_s=cfg.step_deadline_s, on_event=self._on_straggler
        )
        self.history: list[dict] = []
        self.restarts = 0  # cumulative — never decays (observability)
        #: consecutive healthy steps since the last failure — at
        #: ``checkpoint_every`` of them the restart *budget* resets
        #: (``_forgiven`` catches up to ``restarts``), so a long-lived job
        #: survives more than ``max_restarts`` isolated faults while
        #: genuine crash loops still trip the budget
        self._healthy = 0
        self._forgiven = 0
        #: run identity saved into every checkpoint's ``extra`` metadata
        #: (mesh fingerprint, param kind, weight grouping) — the resume
        #: path reads it back to detect an elastic re-mesh
        self.run_meta = run_meta
        self.on_restart = on_restart
        #: transfer engine whose lifecycle this driver owns (closed when the
        #: run finishes or finally fails) — the streamed-optimizer path
        self.engine = engine
        self.stream_stats = stream_stats
        #: DiskHost-tier spill store this driver owns (closed after the
        #: engine so no in-flight disk fetch outlives its chunk files)
        self.spill_store = spill_store

    # ------------------------------------------------------------------ run
    def _on_straggler(self, ev) -> None:
        """A straggling step means the compute side stalled — widen the
        transfer engine's prefetch window so the stream keeps more groups
        in flight and the recovery step is not also transfer-bound."""
        if self.engine is not None and hasattr(self.engine, "widen"):
            widened = self.engine.widen()
            log.info(
                "straggler at step %d (%.3fs, z=%.1f): widened prefetch "
                "distances to %s",
                ev.step, ev.duration_s, ev.z, widened,
            )

    def _restore_or_init(self) -> tuple[int, Pytree]:
        latest = self.ckpt.latest_step()
        if latest is None:
            return 0, self.init_state()
        if self.run_meta and self.run_meta.get("mesh"):
            saved = (self.ckpt.load_meta(latest).get("extra") or {}).get("mesh")
            if saved and saved != self.run_meta["mesh"]:
                log.warning(
                    "elastic re-mesh: checkpoint step %d written on mesh %s, "
                    "restoring onto %s",
                    latest, saved, self.run_meta["mesh"],
                )
        template = jax.eval_shape(self.init_state)
        step, state = self.ckpt.restore(template)
        log.info("restored checkpoint at step %d", step)
        return step + 1, state

    def run(self) -> Pytree:
        try:
            while True:
                try:
                    return self._run_once()
                except Exception as e:  # noqa: BLE001 — the restart loop
                    self.restarts += 1
                    self._healthy = 0
                    log.warning(
                        "step failure (%s); restart %d/%d",
                        e,
                        self.restarts - self._forgiven,
                        self.cfg.max_restarts,
                    )
                    if self.restarts - self._forgiven > self.cfg.max_restarts:
                        raise
                    # a failed step may leave writebacks queued for state
                    # that restore is about to replace — drop them so the
                    # drain after restart only sees post-restore tickets
                    if self.engine is not None and hasattr(
                        self.engine, "discard_writebacks"
                    ):
                        self.engine.discard_writebacks()
                    if self.run_meta and self.run_meta.get("mesh"):
                        from repro.runtime import elastic

                        # raises RemeshRequired when the device count moved:
                        # compiled programs can't re-mesh in-process, the
                        # relaunch path re-shards streamed state on resume
                        elastic.check_restart_mesh(self.run_meta["mesh"])
                    if self.on_restart is not None:
                        self.on_restart(self.restarts)
        finally:
            if self.stream_stats is not None and self.stream_stats.n_groups:
                s = self.stream_stats
                log.info(
                    "transfer engine: %d groups, %.2f req/group, "
                    "wait %.3fs, writeback drain %.3fs, final distance %s",
                    s.n_groups,
                    s.requests_per_group,
                    s.transfer_wait_s,
                    s.writeback_drain_s,
                    s.distance_trace[-1] if s.distance_trace else None,
                )
                if s.cache_hits or s.cache_misses:
                    log.info(
                        "weight residency: %d unique group fetches, "
                        "%d cache hits / %d misses",
                        s.unique_group_fetches,
                        s.cache_hits,
                        s.cache_misses,
                    )
                if s.disk_requests:
                    log.info(
                        "disk tier: %d requests (%.2f/group), %.1f MB, "
                        "h2d-on-disk wait %.3fs",
                        s.disk_requests,
                        s.disk_requests_per_group,
                        s.bytes_disk / 1e6,
                        s.disk_wait_s,
                    )
            if self.engine is not None:
                self.engine.close()
            if self.spill_store is not None:
                self.spill_store.close()

    def _run_once(self) -> Pytree:
        start, state = self._restore_or_init()
        for step in range(start, self.cfg.total_steps):
            self.monitor.start_step(step)
            if step in self.fail_at:
                self.fail_at.discard(step)  # fail once, then recover
                raise RuntimeError(f"injected failure at step {step}")
            batch = self.make_batch(step)
            state, metrics = self.step_fn(state, batch)
            if self.monitor.check_deadline():
                raise TimeoutError(f"step {step} blew deadline (straggler/hang)")
            ev = self.monitor.end_step()
            if ev:
                log.warning("straggler: step %d took %.3fs (z=%.1f)", ev.step, ev.duration_s, ev.z)
            row = {"step": step, **{k: _to_float(v) for k, v in metrics.items()}}
            self.history.append(row)
            self._healthy += 1
            if (
                self.restarts > self._forgiven
                and self.cfg.checkpoint_every
                and self._healthy >= self.cfg.checkpoint_every
            ):
                log.info(
                    "%d healthy steps since last failure: restart budget "
                    "reset (was %d/%d)",
                    self._healthy,
                    self.restarts - self._forgiven,
                    self.cfg.max_restarts,
                )
                self._forgiven = self.restarts
            if self.cfg.log_every and step % self.cfg.log_every == 0:
                log.info("step %d: %s", step, row)
            if self.cfg.checkpoint_every and (step + 1) % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step, state, extra_meta=self.run_meta)
        self.ckpt.save(
            self.cfg.total_steps - 1, state, blocking=True, extra_meta=self.run_meta
        )
        return state


def _to_float(v: Any) -> float:
    try:
        return float(v)
    except Exception:  # noqa: BLE001
        return float("nan")

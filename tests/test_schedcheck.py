"""Static schedule verifier + transfer-hazard sanitizer (ISSUE 9 tentpole).

Pins the verification contract from both sides:
  * zero false positives — every schedule the runtime actually constructs
    analyzes clean (train F/B/O, serve prefill/decode, KV paging, MoE
    expert streaming), and the closed-form ``distance + 2`` window model
    is exact on singleton-unit layouts and an upper bound everywhere;
  * seeded hazards are caught with actionable reports — a budget overrun
    names the phase and group, an in-flight staging reuse raises from the
    engine's free-list pop, a stale-residency RAW raises on the cache hit
    that would serve pre-rebind weights, and a non-draining pager trips
    the KV RAW rule.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import schedcheck as sc
from repro.core.engine import EngineConfig, TransferEngine
from repro.core.residency import ResidencyCache
from repro.core.weightstream import WeightStreamPlan
from repro.train import steps as st


@pytest.fixture(scope="module")
def cfg():
    return dataclasses.replace(get_smoke_config("smollm-360m"), n_layers=4)


@pytest.fixture(scope="module")
def plan(cfg):
    return WeightStreamPlan(cfg, st.abstract_params(cfg), layers_per_group=2)


def _moe_plan():
    cfg = get_smoke_config("mixtral-8x7b")
    return cfg, WeightStreamPlan(
        cfg, st.abstract_params(cfg), layers_per_group=1, expert_stream=True
    )


# ---------------------------------------------------------------------------
# zero false positives: real schedules analyze clean
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["smollm-360m", "recurrentgemma-2b"])
@pytest.mark.parametrize("d", [1, 2, 4])
def test_real_schedules_are_clean(arch, d):
    cfg = get_smoke_config(arch)
    plan = WeightStreamPlan(cfg, st.abstract_params(cfg))
    cap = plan.residency_capacity_bytes()
    rep = sc.analyze_train_schedule(
        plan, distance=d, cache_capacity=cap, spill=True
    )
    assert rep.ok, rep
    assert rep.n_spill_keys == 2 * len(plan.groups)
    srep = sc.analyze_serve_schedule(plan, distance=d, cache_capacity=cap)
    assert srep.ok, srep


def test_moe_routed_schedule_is_clean():
    cfg, plan = _moe_plan()
    rep = sc.analyze_train_schedule(plan, distance=2, spill=True)
    assert rep.ok, rep
    srep = sc.analyze_serve_schedule(
        plan,
        distance=2,
        kv=dict(slots=2, page_len=8, hot_pages=1, page_nbytes=512, max_len=32),
    )
    assert srep.ok, srep
    # the routed fan-in is reported so the report is auditable
    assert any("expert fan-in" in n for n in srep.notes)


def test_budgeted_plan_analyzes_within_its_own_budget(cfg):
    """The plan's budget cap (max_distance_for_budget) must be sound under
    the exact model: stream at the cap, never overrun."""
    free = WeightStreamPlan(cfg, st.abstract_params(cfg), layers_per_group=1)
    budget_mb = free.peak_device_bytes(2) / 1e6
    plan = WeightStreamPlan(
        cfg, st.abstract_params(cfg), layers_per_group=1,
        device_budget_mb=budget_mb,
    )
    d = plan.max_distance_for_budget(cached_bytes=0)
    rep = sc.analyze_train_schedule(plan, distance=d, cached=False)
    assert rep.ok, rep


# ---------------------------------------------------------------------------
# exactness: the d+2 fast path is tight on singleton-unit layouts and an
# upper bound everywhere (the documented peak_device_bytes contract)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["smollm-360m", "recurrentgemma-2b"])
@pytest.mark.parametrize("d", [1, 2, 4])
def test_exact_peak_equals_fast_path_on_singleton_units(arch, d):
    cfg = get_smoke_config(arch)
    plan = WeightStreamPlan(cfg, st.abstract_params(cfg))
    rep = sc.analyze_train_schedule(plan, distance=d, cached=False)
    fwd = next(p for p in rep.phases if p.phase == "forward")
    assert fwd.peak_bytes == plan.peak_device_bytes(d)


@pytest.mark.parametrize("d", [1, 2, 4])
def test_exact_peak_bounded_by_fast_path_on_moe(d):
    _, plan = _moe_plan()
    rep = sc.analyze_train_schedule(plan, distance=d, cached=False)
    fwd = next(p for p in rep.phases if p.phase == "forward")
    assert fwd.peak_bytes <= plan.peak_device_bytes(d)


# ---------------------------------------------------------------------------
# the cache simulator mirrors ResidencyCache decision-for-decision
# ---------------------------------------------------------------------------


def test_cache_sim_mirrors_residency_cache():
    real = ResidencyCache(100)
    sim = sc._CacheSim(100)
    leaf = lambda n: {"w": np.zeros(n, np.uint8)}  # noqa: E731
    ops = [
        ("a", 40, False), ("b", 40, False), ("c", 30, False),  # evicts a
        ("a", 40, True),                                       # evicts b
        ("d", 70, False),                                      # refused: a pinned
        ("c", 30, True),                                       # touch, widen pin
        ("e", 30, False),                                      # refused
    ]
    for key, n, pin in ops:
        assert real.put(key, leaf(n), n, pinned=pin) == sim.put(
            key, n, pinned=pin
        ), (key, n, pin)
        assert real.resident_bytes == sim.resident_bytes
    assert sorted(sim.keys()) == sorted(
        k for k in ("a", "b", "c", "d", "e") if real.peek(k) is not None
    )
    real.unpin_all()
    sim.unpin_all()
    assert real.put("f", leaf(90), 90) == sim.put("f", 90)
    assert real.resident_bytes == sim.resident_bytes == 90


# ---------------------------------------------------------------------------
# seeded hazard 1: budget overrun — named phase + group
# ---------------------------------------------------------------------------


def test_seeded_budget_overrun_names_phase_and_group(cfg, plan):
    budget = plan.peak_device_bytes(1) // 2
    with pytest.raises(sc.ScheduleError) as ei:
        sc.verify_schedule(
            sc.analyze_train_schedule(
                plan, distance=4, cached=False, budget_bytes=budget
            )
        )
    rep = ei.value.report
    v = next(v for v in rep.violations if v.rule == "budget")
    assert v.phase in ("forward", "backward")
    assert v.key in {g.key for g in plan.groups}
    assert v.occupancy_bytes > v.budget_bytes == budget
    assert "exceeds budget" in str(ei.value)


def test_seeded_pin_hazards(cfg, plan):
    rep = sc.analyze_train_schedule(
        plan, distance=1, cache_capacity=10, pin_keys=["nope"]
    )
    assert any(v.rule == "pin-unknown-key" and v.key == "nope"
               for v in rep.violations)
    rep = sc.analyze_train_schedule(
        plan, distance=1, cache_capacity=10,
        pin_keys=[g.key for g in plan.groups],
    )
    assert any(v.rule == "pin-overcommit" for v in rep.violations)


def test_spill_key_collision_detected(plan):
    class Dup:
        groups = plan.groups

        @staticmethod
        def spill_key(g):
            return "wp/same"

    rep = sc.ScheduleReport(
        kind="train", name="dup", layout="uniform", distance=1,
        budget_bytes=None, cache_capacity_bytes=None, cached=False,
    )
    sc._check_spill_keys(Dup, rep)
    assert any(v.rule == "spill-key-collision" for v in rep.violations)


# ---------------------------------------------------------------------------
# seeded hazard 2: in-flight staging reuse — caught at the free-list pop
# ---------------------------------------------------------------------------


def test_seeded_staging_reuse_raises(cfg, plan):
    eng = TransferEngine(EngineConfig(sanitize=True))
    try:
        home = st.init_weight_streamed_params(jax.random.PRNGKey(0), cfg, plan)
        g = plan.groups[0]
        fut = eng.submit_group(g.index, home["groups"][g.key], key=g.key)
        fut.wait()
        # drive the real pool: a clean acquire/release/reacquire cycle
        # passes, then seed the bug — the buffer lands on the free list
        # WITHOUT being released (ticket still in flight) and the next
        # pop refuses
        sig, layout = next(iter(eng._layouts.items()))
        staging = eng._acquire_staging(sig, layout)
        eng._release_staging(sig, staging)
        staging = eng._acquire_staging(sig, layout)  # clean pool reuse
        eng._staging_free[sig].append(staging)
        with pytest.raises(sc.HazardError, match="free list while"):
            eng._acquire_staging(sig, layout)
        assert eng.sanitizer.hazards == 1
    finally:
        eng.close()


def test_sanitizer_staging_unit_semantics():
    san = sc.HazardSanitizer()
    san.on_staging_acquire(0xA, from_pool=False)  # fresh alloc: never flagged
    san.on_staging_release(0xA)
    san.on_staging_acquire(0xA, from_pool=True)  # clean reuse
    with pytest.raises(sc.HazardError, match="reacquired"):
        san.on_staging_acquire(0xA, from_pool=True)  # still marked
    san.on_staging_release(0xA)
    with pytest.raises(sc.HazardError, match="released twice"):
        san.on_staging_release(0xA)


# ---------------------------------------------------------------------------
# seeded hazard 3: stale-residency RAW — hit after the home was rebound
# ---------------------------------------------------------------------------


def test_seeded_stale_residency_raw_raises(cfg, plan):
    home = st.init_weight_streamed_params(jax.random.PRNGKey(0), cfg, plan)
    cache = ResidencyCache(None, sanitize=True)
    g = plan.groups[1]
    tree = plan.fetch_group(home, g, cache)  # miss: marks the home
    cache.put(g.key, tree)
    plan.fetch_group(home, g, cache)  # clean hit: same home
    # the seeded bug: restart/reshard rebinds the host home without
    # ResidencyCache.clear() — the next hit would serve stale weights
    home["groups"][g.key] = jax.tree.map(
        lambda x: np.array(x) + 1, home["groups"][g.key]
    )
    with pytest.raises(sc.HazardError, match=g.key):
        plan.fetch_group(home, g, cache)


def test_engine_raw_writeback_fetch_raises():
    eng = TransferEngine(EngineConfig(sanitize=True))
    try:
        arr = jax.device_put(np.ones(64, np.float32))
        eng.submit_writeback(1, {"w": arr}, key="g001")
        with pytest.raises(sc.HazardError, match="g001"):
            eng.submit_group(0, {"w": np.ones(64, np.float32)}, key="g001")
        eng.discard_writebacks()  # drained: the same fetch is now legal
        eng.submit_group(0, {"w": np.ones(64, np.float32)}, key="g001").wait()
    finally:
        eng.close()


def test_static_raw_detected_without_drain():
    """The analyzer's O-phase writeback hazard rule, driven directly."""
    _, plan = _moe_plan()
    rep = sc.ScheduleReport(
        kind="train", name="x", layout=plan.layout, distance=1,
        budget_bytes=None, cache_capacity_bytes=None, cached=False,
    )
    sim = sc._PhaseSim(rep, "optimizer", cache=None, budget_bytes=None)
    g = plan.groups[0]
    sim.submit(g, 8, g.key)
    sim.writeback(g.key)
    sim.submit(g, 8, g.key)  # re-fetch before the drain
    assert any(v.rule == "raw-writeback" and v.key == g.key
               for v in rep.violations)


def test_kv_raw_detected_when_pager_skips_drain():
    cfg = get_smoke_config("smollm-360m")
    plan = WeightStreamPlan(cfg, st.abstract_params(cfg))
    rep = sc.analyze_serve_schedule(
        plan,
        distance=1,
        kv=dict(slots=1, page_len=4, hot_pages=1, page_nbytes=256, max_len=64),
        flush_demotions=False,
    )
    assert any(v.rule == "kv-raw" and v.key.startswith("kv/")
               for v in rep.violations), rep


# ---------------------------------------------------------------------------
# env plumbing + report rendering
# ---------------------------------------------------------------------------


def test_sanitize_enabled_env(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert sc.sanitize_enabled() is False
    assert sc.sanitize_enabled(default=True) is True
    for v, want in [("1", True), ("true", True), ("0", False),
                    ("no", False), ("", False)]:
        monkeypatch.setenv("REPRO_SANITIZE", v)
        assert sc.sanitize_enabled() is want
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert EngineConfig().sanitize is True
    assert ResidencyCache(None).sanitize is True


def test_report_renders_violations(cfg, plan):
    rep = sc.analyze_train_schedule(
        plan, distance=4, cached=False, budget_bytes=1
    )
    text = str(rep)
    assert "VIOLATIONS" in text and "schedule[train]" in text
    clean = sc.analyze_train_schedule(plan, distance=1)
    assert "OK:" in str(clean) and clean.peak_bytes > 0

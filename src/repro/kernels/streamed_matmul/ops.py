"""Public jit'd wrapper for the streamed matmul kernel.

Handles block padding, batch-dim flattening, dtype policy, and backend
dispatch (interpret on CPU; compiled Mosaic on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.refspec import PrefetchSpec
from repro.kernels.streamed_matmul.kernel import streamed_matmul_p

_DEFAULT_SPEC = PrefetchSpec(buffer_size=2, elements_per_fetch=1, distance=1)


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(
    jax.jit,
    static_argnames=("spec", "block_m", "block_n", "block_k", "interpret"),
)
def streamed_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    spec: PrefetchSpec = _DEFAULT_SPEC,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """``y[..., n] = x[..., k] @ w[k, n]`` with HBM-resident, ring-prefetched w.

    ``x`` may carry leading batch dims; they are flattened into M. Shapes are
    padded up to block multiples and the result is sliced back, so any shape
    is accepted.  Semantics match :func:`repro.kernels.streamed_matmul.ref.
    matmul_ref` for every ``PrefetchSpec`` (property-tested).
    """
    if interpret is None:
        interpret = _on_cpu()
    *lead, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, k)

    bm = min(block_m, _ceil_to(m, 8))
    bn = min(block_n, _ceil_to(n, 128))
    bk = min(block_k, _ceil_to(k, 128))
    mp, np_, kp = _ceil_to(m, bm), _ceil_to(n, bn), _ceil_to(k, bk)
    xp = jnp.pad(x2, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    out = streamed_matmul_p(
        xp, wp, spec=spec, block_m=bm, block_n=bn, block_k=bk, interpret=interpret
    )
    return out[:m, :n].reshape(*lead, n)

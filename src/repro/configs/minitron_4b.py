"""Minitron-4B [arXiv:2407.14679; hf:nvidia/Minitron-4B-Base].

32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000 — pruned Nemotron:
squared-ReLU MLP, LayerNorm, RoPE, untied (large 256k vocab).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256_000,
    mlp_type="relu2",
    norm_type="layernorm",
    pos_type="rope",
    rope_theta=10_000.0,
    source="arXiv:2407.14679; hf",
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=192, vocab_size=512, remat="none",
    )

"""End-to-end training launcher.

Wires every layer of the framework together: config -> mesh -> sharding plan
-> jitted train step -> prefetching loader -> fault-tolerant driver with
checkpointing.  Runs real training on whatever devices exist (CPU smoke
configs here; the same code path jits for pods), e.g.::

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \\
      --steps 50 --batch 8 --seq 64 --checkpoint-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import logging
import time

import jax
import jax.numpy as jnp
from repro.configs import ARCHS, get_config, get_smoke_config
from repro.data.loader import PrefetchLoader
from repro.data.synthetic import SyntheticConfig, synthetic_batch
from repro.optim.adamw import AdamWConfig
from repro.parallel import sharding as sh
from repro.runtime.driver import DriverConfig, TrainDriver
from repro.train import steps as st


def _shardings(mesh, specs):
    return sh.named_shardings(mesh, specs)


def build_trainer(
    cfg,
    mesh,
    *,
    global_batch: int,
    seq_len: int,
    opt_cfg: AdamWConfig,
    driver_cfg: DriverConfig,
    seed: int = 0,
    fail_at=None,
    prefetch_distance: int = 2,
    policy=None,
    stream_opt: bool = False,
    opt_stream_groups: int = 4,
    spill_dir=None,
    host_budget_mb=None,
    param_kind: str = "device",
    device_budget_mb=None,
    param_layers_per_group=None,
    expert_stream: bool = False,
    transfer_retries: int = 1,
    verify_schedule: bool = False,
):
    """Assemble (driver, jitted step) for a config on a mesh.

    ``policy`` (repro.core.memkind.PlacementPolicy) chooses the memory kind
    of each state group — the paper's one-line placement change.  With
    ``HOST_OPT`` the AdamW state lives at the pinned-host kind between
    steps; the runtime streams it to the device for the update and back
    (on backends without host-offload execution the kinds fall back to
    device with identical program topology, see memkind docs).

    ``stream_opt`` upgrades a host-kind optimizer policy from bulk
    step-boundary copies to the transfer-engine streamed update: moments
    live on the host as numpy groups and stream through
    ``repro.core.engine.TransferEngine`` (coalesced, pipelined write-back,
    ``distance="auto"``) during the update itself.

    With a ``DISK_OPT`` policy (or an explicit ``spill_dir``), moment
    groups that do not fit ``host_budget_mb`` spill to a ``DiskHost``
    :class:`~repro.core.spillstore.SpillStore` and stream through the
    engine's two-stage disk->host->device pipeline — optimizer state
    larger than host RAM, same update values.

    ``param_kind`` (``--param-kind``) extends the hierarchy to the model
    **weights**: ``pinned_host``/``disk_host`` home the params (and their
    AdamW moments) off-device and stream them layer-group-wise through the
    engine for the forward pass, the reverse-order backward pass, and the
    optimizer update (see ``repro.core.weightstream``), with
    ``device_budget_mb`` bounding peak streamed device residency — models
    of arbitrarily large size under an explicit device budget.  This path
    subsumes ``--stream-opt`` (the moments ride the same groups).

    ``transfer_retries`` sets the engine's transient-fault budget
    (``EngineConfig.max_attempts``): H2D/D2H/disk-stage faults retry with
    exponential backoff before surfacing, re-fetching from the intact cold
    home — retried schedules stay bitwise-equal.

    Resuming a weight-streamed run is **elastic**: the launcher fingerprints
    the mesh into every checkpoint, and when the latest checkpoint's weight
    grouping no longer matches the (re-derived) plan it is re-partitioned
    in place by streaming (``repro.runtime.elastic``) before restore.
    """
    from repro.core import memkind as mk
    from repro.core import spillstore as st_mod
    from repro.core.engine import EngineConfig, TransferEngine
    from repro.core.hoststream import StreamStats
    from repro.core.refspec import PrefetchSpec
    from repro.core.spillstore import SpillStore

    policy = policy or mk.ALL_DEVICE
    plan = sh.make_plan(mesh, mode="train")
    params_abs, opt_abs = st.abstract_train_state(cfg)
    p_specs = sh.param_specs(plan, params_abs)
    o_specs = sh.opt_state_specs(plan, p_specs, params_abs)
    p_sh, o_sh = _shardings(mesh, p_specs), _shardings(mesh, o_specs)
    sharder = sh.make_sharder(
        plan, params_abs, global_batch, seq_len=seq_len, seq_shard=True
    )

    step_fn = st.make_train_step(cfg, opt_cfg, mesh, sharder)
    jitted = jax.jit(
        step_fn,
        in_shardings=(p_sh, o_sh, None),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1),
    )

    sc = SyntheticConfig(cfg.vocab_size, seq_len, global_batch, seed=seed)
    loader = PrefetchLoader(
        lambda step: synthetic_batch(cfg, sc, step), distance=prefetch_distance
    )

    def _opt_home(opt):
        """Place the optimizer state at its policy kind (host offload)."""
        if policy.opt_state.jax_kind == "device":
            return opt
        home = jax.tree.map(
            lambda s: mk.sharding_for(mesh, s.spec, policy.opt_state),
            o_sh,
            is_leaf=lambda s: isinstance(s, jax.sharding.NamedSharding),
        )
        return jax.device_put(opt, home)

    def init_state():
        params, opt = st.init_train_state(jax.random.PRNGKey(seed), cfg)
        with mesh:
            params = jax.device_put(params, p_sh)
            opt = jax.device_put(opt, o_sh)
            opt = _opt_home(opt)
        return {"params": params, "opt": opt}

    def wrapped_step(state, batch):
        with mesh:
            opt = jax.device_put(state["opt"], o_sh)  # stream in from home kind
            params, opt, metrics = jitted(state["params"], opt, batch)
            opt = _opt_home(opt)  # stream back (paper 'rw' write-back)
        return {"params": params, "opt": opt}, metrics

    log = logging.getLogger("repro.train")
    if expert_stream and param_kind == "device":
        raise ValueError(
            "--expert-stream streams routed experts from a weight home; "
            "it requires --param-kind pinned_host or disk_host"
        )
    if param_kind != "device":
        from repro.core.weightstream import (
            PARAM_KINDS,
            WeightStreamPlan,
            weight_stream_support,
        )

        if param_kind not in PARAM_KINDS:
            raise ValueError(
                f"unknown --param-kind {param_kind!r}; expected one of {PARAM_KINDS}"
            )
        support = weight_stream_support(cfg)
        if not support:
            raise ValueError(f"--param-kind {param_kind}: {support.reason}")
        if stream_opt:
            log.warning(
                "--stream-opt is subsumed by --param-kind %s: the AdamW "
                "moments are homed with the params and stream through the "
                "same groups",
                param_kind,
            )
        plan = WeightStreamPlan(
            cfg,
            st.abstract_params(cfg),
            layers_per_group=param_layers_per_group,
            device_budget_mb=device_budget_mb,
            expert_stream=expert_stream,
        )
        log.info(
            "weight streaming: %s program, %d groups (%d layers/group), "
            "total %.1f MB, peak(d=1) %.1f MB, max distance %d",
            plan.layout,
            plan.n_groups,
            plan.layers_per_group,
            plan.total_param_bytes / 1e6,
            plan.peak_device_bytes(1) / 1e6,
            plan.max_distance_for_budget(),
        )
        # weight-residency cache: the budget slack above the prefetch
        # window keeps recently fetched groups device-resident, so the
        # backward re-walk (and the next step's forward) hits instead of
        # re-fetching — window + cache still never exceed the budget
        from repro.core.residency import ResidencyCache

        residency = ResidencyCache(plan.residency_capacity_bytes())
        log.info(
            "weight residency cache: %s capacity",
            "unbounded"
            if residency.capacity_bytes is None
            else f"{residency.capacity_bytes / 1e6:.1f} MB",
        )
        if verify_schedule:
            # --verify-schedule: print the static analysis (the streamed
            # step re-runs it at construction and fails fast regardless)
            from repro.core import schedcheck

            report = schedcheck.analyze_train_schedule(
                plan,
                distance=plan.max_distance_for_budget(),
                cache_capacity=residency.capacity_bytes,
                spill=param_kind == "disk_host",
            )
            print(report)
            schedcheck.verify_schedule(report)
        engine = TransferEngine(
            EngineConfig(
                max_distance=plan.max_distance_for_budget(),
                max_attempts=transfer_retries,
            )
        )
        param_stats = StreamStats()
        param_store = None
        if param_kind == "disk_host":
            ephemeral = spill_dir is None
            if ephemeral:
                import tempfile

                spill_dir = tempfile.mkdtemp(prefix="repro-spill-wp-")
            param_store = SpillStore(spill_dir, ephemeral=ephemeral)

        from repro.runtime import elastic as el

        run_meta = {
            "mesh": el.mesh_fingerprint(mesh),
            "param_kind": param_kind,
            "weight_groups": plan.grouping(),
        }
        # elastic resume: if the latest checkpoint was written under a
        # different grouping (re-meshed budget, changed group size), stream-
        # repartition it in place before the driver restores
        resharded = el.ensure_plan_matches_checkpoint(
            driver_cfg.checkpoint_dir, plan, mesh=mesh, run_meta=run_meta
        )
        if resharded and param_store is not None:
            el.prune_stale_spill(param_store, plan)
        streamed = st.make_weight_streamed_train_step(
            cfg,
            opt_cfg,
            mesh,
            sharder,
            plan=plan,
            engine=engine,
            stats=param_stats,
            spill_store=param_store,
            # groups stage at the sharding plan's param specs under a mesh
            param_shardings=p_sh if mesh.devices.size > 1 else None,
            param_kind=param_kind,
            residency=residency,
        )

        def init_state_ws():
            state = st.init_weight_streamed_state(
                jax.random.PRNGKey(seed), cfg, plan
            )
            if param_store is not None:
                state = st.spill_weight_streamed_state(plan, state, param_store)
            return state

        def wrapped_step_ws(state, batch):
            if param_store is not None and not plan.is_spilled(state["params"]):
                # checkpoint restore hands back plain host arrays — the
                # disk home must be re-imposed or the weights sit in RAM
                state = st.spill_weight_streamed_state(plan, state, param_store)
            with mesh:
                return streamed(state, batch)

        def on_restart_ws(_n):
            # restart restores an older checkpoint (or re-inits), so cached
            # device copies no longer match the home — a failure *outside*
            # the step (checkpoint commit, watchdog) skips the step's own
            # failure clear, so the restart hook must drop them too
            residency.clear()
            # a kill mid-drain can leave D2H tickets pending; the restored
            # step must never drain them into its outputs, and the hazard
            # sanitizer would (correctly) flag the re-fetch of their groups
            engine.discard_writebacks()

        driver = TrainDriver(
            driver_cfg,
            wrapped_step_ws,
            loader,
            init_state_ws,
            fail_at=fail_at,
            engine=engine,
            stream_stats=param_stats,
            spill_store=param_store,
            run_meta=run_meta,
            on_restart=on_restart_ws,
        )
        return driver

    from repro.runtime import elastic as el

    run_meta = {"mesh": el.mesh_fingerprint(mesh), "param_kind": param_kind}

    if stream_opt and policy.opt_state.jax_kind == "device":
        log.warning(
            "--stream-opt ignored: policy %r keeps optimizer state on "
            "device; use --policy host_opt (or host_all) to stream it",
            policy.name,
        )
    if not policy.params.jax_addressable or not policy.kv_cache.jax_addressable:
        # this launcher only streams *optimizer state* from disk; disk-kind
        # params/kv resolve to their staging kind (host), which must not
        # pass silently for someone expecting larger-than-RAM weights
        log.warning(
            "policy %r places params/kv at the DiskHost tier, but the "
            "trainer has no disk-params streaming path: they fall back to "
            "the host staging kind (use @offload(...).stream_host(policy="
            "DISK_PARAMS) for disk-resident weights)",
            policy.name,
        )
    if not stream_opt and not policy.opt_state.jax_addressable:
        log.warning(
            "policy %r without --stream-opt never touches disk: the "
            "DiskHost kind resolves to its host staging kind for bulk "
            "step-boundary copies; pass --stream-opt to stream the "
            "moments through the spill store",
            policy.name,
        )
    if stream_opt and policy.opt_state.jax_kind != "device":
        # engine-streamed optimizer: moments stay host numpy between steps;
        # under a DISK_OPT policy (or a host policy with an explicit
        # spill_dir + budget) groups beyond the host-RAM budget live on
        # disk and stream disk->host->device
        engine = TransferEngine(EngineConfig(max_attempts=transfer_retries))
        stream_stats = StreamStats()
        spill_store = None
        use_spill = not policy.opt_state.jax_addressable or (
            spill_dir is not None and host_budget_mb is not None
        )
        if spill_dir is not None and host_budget_mb is None and not use_spill:
            log.warning(
                "--spill-dir ignored: policy %r is host-resident and no "
                "--host-budget-mb overflow threshold was given",
                policy.name,
            )
        if use_spill:
            ephemeral = spill_dir is None
            if ephemeral:
                import tempfile

                spill_dir = tempfile.mkdtemp(prefix="repro-spill-opt-")
            # a run-private temp store is ephemeral: no per-put durability
            # cost on the train hot path, deleted by the driver's close()
            spill_store = SpillStore(spill_dir, ephemeral=ephemeral)
        streamed = st.make_streamed_train_step(
            cfg,
            opt_cfg,
            mesh,
            sharder,
            n_groups=opt_stream_groups,
            prefetch=PrefetchSpec(
                buffer_size=opt_stream_groups + 1, distance="auto"
            ),
            engine=engine,
            stats=stream_stats,
            spill_store=spill_store,
            # moments stage at the plan's opt specs (sharded coalescing:
            # one H2D request per device per group under --model-parallel)
            state_shardings=o_sh["leaves"],
        )

        budget_bytes = int(host_budget_mb * 1e6) if host_budget_mb else 0

        def _spilled(opt):
            return st.spill_opt_state(
                opt,
                spill_store,
                n_groups=opt_stream_groups,
                host_budget_bytes=budget_bytes,
            )

        def init_state_streamed():
            params, _ = st.init_train_state(jax.random.PRNGKey(seed), cfg)
            with mesh:
                params = jax.device_put(params, p_sh)
            opt = st.host_opt_state(params)
            if spill_store is not None:
                opt = _spilled(opt)
            return {"params": params, "opt": opt}

        def wrapped_step_streamed(state, batch):
            if spill_store is not None and not any(
                st_mod.is_disk_leaf(x)
                for x in jax.tree.leaves(state["opt"]["leaves"])
            ):
                # checkpoint restore hands back plain host arrays — the
                # budget must be re-imposed or the whole state sits in RAM
                state = {**state, "opt": _spilled(state["opt"])}
            with mesh:
                return streamed(state, batch)

        driver = TrainDriver(
            driver_cfg,
            wrapped_step_streamed,
            loader,
            init_state_streamed,
            fail_at=fail_at,
            engine=engine,
            stream_stats=stream_stats,
            spill_store=spill_store,
            run_meta=run_meta,
        )
        return driver

    driver = TrainDriver(
        driver_cfg,
        wrapped_step,
        loader,
        init_state,
        fail_at=fail_at,
        run_meta=run_meta,
    )
    return driver


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS, required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--checkpoint-dir", default="checkpoints")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--policy",
        default="all_device",
        choices=[
            "all_device", "host_opt", "host_params", "host_all",
            "disk_opt", "disk_params",
        ],
        help="memory-kind placement policy (paper memory kinds; disk_* "
        "spill to the DiskHost tier)",
    )
    ap.add_argument(
        "--stream-opt",
        action="store_true",
        help="stream host-kind optimizer state through the transfer engine "
        "(coalesced + pipelined write-back + adaptive prefetch distance)",
    )
    ap.add_argument(
        "--spill-dir",
        default=None,
        help="directory for the DiskHost spill store (default: a temp dir "
        "when a disk policy is active)",
    )
    ap.add_argument(
        "--host-budget-mb",
        type=float,
        default=None,
        help="host-RAM budget for streamed optimizer state; moment groups "
        "beyond it spill to the DiskHost tier (0/unset with a disk "
        "policy: spill everything)",
    )
    from repro.core.weightstream import PARAM_KINDS

    ap.add_argument(
        "--param-kind",
        default="device",
        choices=PARAM_KINDS,
        help="home tier of the model weights: host/disk kinds stream the "
        "params (and their AdamW moments) layer-group-wise through the "
        "transfer engine for forward, reverse-order backward, and the "
        "optimizer update",
    )
    ap.add_argument(
        "--device-budget-mb",
        type=float,
        default=None,
        help="device-residency budget for streamed weights: picks the "
        "layer-group size and caps the prefetch window so streamed "
        "params never exceed it",
    )
    ap.add_argument(
        "--param-layers-per-group",
        type=int,
        default=None,
        help="layers per weight transfer group (default: largest count "
        "fitting --device-budget-mb, else n_layers/4)",
    )
    ap.add_argument(
        "--expert-stream",
        action="store_true",
        help="split MoE experts into per-expert fetch groups (train "
        "overlaps all-expert fetch with compute; requires a streamed "
        "--param-kind and an MoE arch)",
    )
    ap.add_argument(
        "--fail-at",
        default=None,
        help="comma-separated step numbers at which to inject one failure "
        "each (chaos testing: exercises restart + restore)",
    )
    ap.add_argument(
        "--max-restarts",
        type=int,
        default=3,
        help="restart budget; the budget resets after checkpoint-every "
        "consecutive healthy steps",
    )
    ap.add_argument(
        "--transfer-retries",
        type=int,
        default=3,
        help="transfer-engine attempt budget for transient H2D/D2H/disk "
        "faults (1 = fail fast, legacy behavior)",
    )
    ap.add_argument(
        "--history-out",
        default=None,
        help="write the per-step metric history as JSON to this path "
        "(chaos tests diff loss series across runs bitwise)",
    )
    ap.add_argument(
        "--verify-schedule",
        action="store_true",
        help="statically verify the streamed-weight schedule before "
        "running (print the per-phase occupancy/hazard analysis, fail "
        "fast on any violation; see repro.core.schedcheck)",
    )
    args = ap.parse_args()
    if args.verify_schedule and args.param_kind == "device":
        ap.error("--verify-schedule requires a streamed --param-kind "
                 "(pinned_host or disk_host)")

    logging.basicConfig(level=logging.INFO, format="%(levelname)s %(message)s")
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    # elastic: degrade the model axis instead of asserting when the device
    # count changed since the job was first launched
    from repro.runtime.elastic import elastic_local_mesh

    mesh = elastic_local_mesh(model=args.model_parallel)
    opt_cfg = AdamWConfig(
        peak_lr=args.lr, warmup_steps=max(args.steps // 10, 1), total_steps=args.steps
    )
    driver_cfg = DriverConfig(
        total_steps=args.steps,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
        max_restarts=args.max_restarts,
    )
    fail_at = (
        {int(s) for s in args.fail_at.split(",") if s.strip()}
        if args.fail_at
        else None
    )
    from repro.core import memkind as mk

    driver = build_trainer(
        cfg,
        mesh,
        global_batch=args.batch,
        seq_len=args.seq,
        opt_cfg=opt_cfg,
        driver_cfg=driver_cfg,
        seed=args.seed,
        fail_at=fail_at,
        policy=mk.get_policy(args.policy),
        stream_opt=args.stream_opt,
        spill_dir=args.spill_dir,
        host_budget_mb=args.host_budget_mb,
        param_kind=args.param_kind,
        device_budget_mb=args.device_budget_mb,
        param_layers_per_group=args.param_layers_per_group,
        expert_stream=args.expert_stream,
        transfer_retries=args.transfer_retries,
        verify_schedule=args.verify_schedule,
    )
    t0 = time.time()
    driver.run()
    dt = time.time() - t0
    if args.history_out:
        import json

        with open(args.history_out, "w") as f:
            json.dump(driver.history, f)
    losses = [h["loss"] for h in driver.history if "loss" in h]
    span = f"loss {losses[0]:.3f} -> {losses[-1]:.3f}" if losses else "no new steps"
    print(
        f"trained {args.arch} ({'smoke' if args.smoke else 'full'}) "
        f"{len(driver.history)} steps in {dt:.1f}s; {span}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

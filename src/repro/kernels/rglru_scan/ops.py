"""Public jit'd wrapper for the linear-recurrence kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.kernel import linear_recurrence_p


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("chunk_t", "block_w", "interpret"))
def linear_recurrence(
    a: jax.Array,  # (B, S, W)
    b: jax.Array,
    *,
    chunk_t: int = 128,
    block_w: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """``h_t = a_t h_{t-1} + b_t`` over axis 1, h_0 = 0; matches
    ``ref.linear_recurrence_ref``.

    Padding: time is padded with (a=1, b=0) — identity steps — and channels
    with zeros; both are sliced away.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bsz, s, w = a.shape
    ct = min(chunk_t, _ceil_to(s, 8))
    bw = min(block_w, _ceil_to(w, 128))
    sp, wp = _ceil_to(s, ct), _ceil_to(w, bw)
    ap = jnp.pad(a, ((0, 0), (0, sp - s), (0, wp - w)), constant_values=1.0)
    if wp != w:  # channel padding must not see a=1 with b=0 junk; zero is fine
        ap = ap.at[:, :, w:].set(0.0)
    bp = jnp.pad(b, ((0, 0), (0, sp - s), (0, wp - w)))
    out = linear_recurrence_p(ap, bp, chunk_t=ct, block_w=bw, interpret=interpret)
    return out[:, :s, :w]

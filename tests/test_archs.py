"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
output shapes + no NaNs (assignment deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config, input_specs
from repro.configs.base import SHAPES
from repro.models import transformer
from repro.optim.adamw import AdamWConfig
from repro.train import steps as st


def _smoke_batch(cfg, b=2, s=16, key=jax.random.PRNGKey(7)):
    if cfg.n_codebooks:
        toks = jax.random.randint(key, (b, cfg.n_codebooks, s), 0, cfg.vocab_size)
        return {"codes": toks, "targets": toks}
    batch = {
        "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
    }
    vis = 0
    if cfg.vision_embed:
        vis = 4
        batch["vision_embeds"] = jnp.zeros((b, vis, cfg.d_model), jnp.bfloat16)
    if cfg.pos_type == "mrope":
        batch["positions_3d"] = jnp.broadcast_to(
            jnp.arange(s + vis, dtype=jnp.int32)[None, None], (b, 3, s + vis)
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_loss(arch):
    cfg = get_smoke_config(arch)
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg)
    loss, metrics = transformer.lm_loss(cfg, params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0
    logits, aux = transformer.forward_train(cfg, params, batch)
    assert not np.any(np.isnan(np.asarray(logits, np.float32)))
    if cfg.n_codebooks:
        assert logits.shape[:2] == (2, cfg.n_codebooks)
        assert logits.shape[-1] == cfg.vocab_size
    else:
        assert logits.shape[-1] == cfg.vocab_size


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_improves_or_finite(arch):
    """One real optimizer step: loss finite before and after, params move."""
    cfg = get_smoke_config(arch)
    params, opt = st.init_train_state(jax.random.PRNGKey(0), cfg)
    step = st.make_train_step(cfg, AdamWConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10))
    batch = _smoke_batch(cfg)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # params actually changed
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert delta > 0, arch
    assert int(new_opt["step"]) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_decode_consistency(arch):
    """Greedy decode after prefill == greedy scoring of the full sequence."""
    cfg = get_smoke_config(arch)
    if cfg.pos_type == "mrope":
        pytest.skip("mrope decode needs per-step 3D positions (covered in dryrun)")
    if cfg.n_experts:
        # capacity-based MoE drops different tokens for a 12-token batch vs
        # a 1-token decode; no-drop capacity isolates the cache semantics
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.n_experts) / cfg.moe_top_k + 1.0
        )
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    b, s = 2, 12
    key = jax.random.PRNGKey(5)
    if cfg.n_codebooks:
        toks = jax.random.randint(key, (b, cfg.n_codebooks, s), 1, cfg.vocab_size)
        batch_full = {"codes": toks}
        batch_prefix = {"codes": toks[..., :-1]}
        step_batch = {"codes": toks[..., -1:]}
    else:
        toks = jax.random.randint(key, (b, s), 1, cfg.vocab_size)
        batch_full = {"tokens": toks}
        batch_prefix = {"tokens": toks[:, :-1]}
        step_batch = {"tokens": toks[:, -1:]}

    caches = transformer.init_caches(cfg, b, s + 4)
    _, caches = transformer.prefill(cfg, params, batch_prefix, caches)
    logits_dec, _ = transformer.decode_step(
        cfg, params, step_batch, caches, jnp.asarray(s - 1, jnp.int32)
    )
    # reference: full forward, last position
    logits_full, _ = transformer.forward_train(cfg, params, batch_full)
    ref = logits_full[..., -1:, :]
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(ref, np.float32),
        rtol=0.15, atol=0.2,  # bf16 state + different contraction orders
    )


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The exact assigned hyperparameters (guards against config drift)."""
    expected = {
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab_size)
    assert got == expected, (arch, got, expected)
    if arch == "qwen3-moe-235b-a22b":
        assert (cfg.n_experts, cfg.moe_top_k) == (128, 8)
    if arch == "mixtral-8x7b":
        assert (cfg.n_experts, cfg.moe_top_k) == (8, 2)
        assert cfg.attn_type == "swa" and cfg.window > 0


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_complete(arch, shape):
    cfg = get_config(arch)
    specs = input_specs(cfg, shape)
    assert specs, (arch, shape)
    for name, s in specs.items():
        assert isinstance(s, jax.ShapeDtypeStruct), name
        assert all(d > 0 for d in s.shape)


def test_chunked_attention_equals_xla():
    cfg = get_smoke_config("internlm2-20b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    batch = _smoke_batch(cfg, s=64)
    lx, _ = transformer.lm_loss(dataclasses.replace(cfg, attn_impl="xla"), params, batch)
    lc, _ = transformer.lm_loss(
        dataclasses.replace(cfg, attn_impl="chunked", attn_chunk_q=16), params, batch
    )
    assert abs(float(lx) - float(lc)) < 1e-4

"""Paper Table 2 analogue: per-transfer stall time vs transfer size.

The paper's synthetic benchmark measures the time a micro-core stalls per
single load for 128B / 1KB / 8KB transfers, on-demand vs prefetch, and finds
them *nearly identical per transfer* — the end-to-end gap (Fig 3/4) comes
from request COUNT.  We reproduce: stall = time the consumer blocks on one
host->device transfer; prefetch hides it by issuing ``distance`` ahead.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C


def _measure(size_bytes: int, *, prefetch: bool, n: int = 64) -> dict:
    elems = max(size_bytes // 4, 1)
    host = [np.random.randn(elems).astype(np.float32) for _ in range(n)]

    @jax.jit
    def consume(acc, x):
        return acc + jnp.sum(x)

    acc = jnp.zeros(())
    stalls = []
    if prefetch:
        inflight = [jax.device_put(host[0]), jax.device_put(host[1])]
        for i in range(n):
            if i + 2 < n:
                inflight.append(jax.device_put(host[i + 2]))
            t0 = time.perf_counter()
            buf = inflight.pop(0)
            jax.block_until_ready(buf)  # stall only if the copy isn't done
            stalls.append(time.perf_counter() - t0)
            acc = consume(acc, buf)
    else:
        for i in range(n):
            t0 = time.perf_counter()
            buf = jax.device_put(host[i])  # issued at use time: full stall
            jax.block_until_ready(buf)
            stalls.append(time.perf_counter() - t0)
            acc = consume(acc, buf)
    jax.block_until_ready(acc)
    stalls = stalls[4:]  # drop warmup
    return {
        "min_ms": min(stalls) * 1e3,
        "max_ms": max(stalls) * 1e3,
        "mean_ms": float(np.mean(stalls)) * 1e3,
    }


def main() -> int:
    rows = []
    for size in (128, 1024, 8192, 262144, 2 ** 20):
        for mode in ("on_demand", "prefetch"):
            r = _measure(size, prefetch=(mode == "prefetch"))
            rows.append({"size": size, "mode": mode, **r})
    C.print_table("paper Table 2 analogue: stall time per transfer (ms)", rows,
                  ["size", "mode", "min_ms", "mean_ms", "max_ms"])
    C.save_rows("table2_stall", rows)
    # claim: per-transfer stall is comparable across modes at small sizes
    small = [r for r in rows if r["size"] <= 8192]
    od = np.mean([r["mean_ms"] for r in small if r["mode"] == "on_demand"])
    pf = np.mean([r["mean_ms"] for r in small if r["mode"] == "prefetch"])
    print(f"small-transfer mean stall: on_demand {od:.4f} ms vs prefetch {pf:.4f} ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

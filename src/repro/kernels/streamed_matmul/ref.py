"""Pure-jnp oracle for the streamed matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """``y = x @ w`` with f32 accumulation, result in ``x.dtype``.

    Semantics the kernel must match for every PrefetchSpec setting (paper
    §3.1: "the prefetch argument does not impact the correctness of the
    code, the result of computation is identical with and without
    pre-fetching").
    """
    acc = jnp.dot(
        x.astype(jnp.float32), w.astype(jnp.float32), preferred_element_type=jnp.float32
    )
    return acc.astype(x.dtype)

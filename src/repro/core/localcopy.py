"""Paper §3.3 memory model: local-copy preference with write-through.

"whenever a micro-core attempts to access a scalar variable or index of an
array, held elsewhere in the memory hierarchy, preference is given to any
local copy held on that micro-core. If there is no local copy, then a data
transfer will be performed. [...] the write occurs both to the local copy
and is also written back to the variable's location on the host."

``LocalCopyCache`` is that model at framework granularity: a bounded pool of
device-resident views over host-kind arrays.  Reads hit the local copy when
present (paper: ``tmp = a; a = tmp * a`` fetches once); writes update the
local copy AND write through to the home buffer; capacity eviction mirrors
the paper's "locally held copies of data may be freed" for the on-demand
central pool.  Within a device, operations are in program order; across
devices only atomicity per chunk is guaranteed (no cross-core ordering) —
documented, as in the paper.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Optional

import jax
import numpy as np

Pytree = Any


class LocalCopyCache:
    def __init__(self, *, capacity_bytes: int = 64 * 2**20, sharding=None) -> None:
        self.capacity = capacity_bytes
        self._sharding = sharding
        self._local: "OrderedDict[str, jax.Array]" = OrderedDict()
        self._home: dict[str, np.ndarray] = {}
        self.stats = {"hits": 0, "misses": 0, "evictions": 0, "writebacks": 0}

    # -- home registration (the variable's location in the hierarchy) -------
    def register(self, name: str, value: np.ndarray) -> None:
        self._home[name] = np.asarray(value)

    def home(self, name: str) -> np.ndarray:
        return self._home[name]

    # -- reads ---------------------------------------------------------------
    def read(self, name: str) -> jax.Array:
        """Local copy preferred; fetch (H2D) on miss."""
        if name in self._local:
            self.stats["hits"] += 1
            self._local.move_to_end(name)
            return self._local[name]
        self.stats["misses"] += 1
        buf = (
            jax.device_put(self._home[name], self._sharding)
            if self._sharding is not None
            else jax.device_put(self._home[name])
        )
        self._insert(name, buf)
        return buf

    # -- writes: local + write-through ----------------------------------------
    def write(self, name: str, value: jax.Array) -> None:
        self._insert(name, value)
        self._home[name] = np.asarray(jax.device_get(value))  # write-through
        self.stats["writebacks"] += 1

    # -- pool management (paper: central storage pool, copies may be freed) ---
    def _insert(self, name: str, buf: jax.Array) -> None:
        self._local[name] = buf
        self._local.move_to_end(name)
        while self._bytes() > self.capacity and len(self._local) > 1:
            evicted, _ = self._local.popitem(last=False)
            self.stats["evictions"] += 1

    def _bytes(self) -> int:
        return sum(b.size * b.dtype.itemsize for b in self._local.values())

    def invalidate(self, name: Optional[str] = None) -> None:
        if name is None:
            self._local.clear()
        else:
            self._local.pop(name, None)

from repro.runtime.driver import TrainDriver, DriverConfig
from repro.runtime.elastic import elastic_mesh_shape
from repro.runtime.straggler import StragglerMonitor

__all__ = [
    "TrainDriver",
    "DriverConfig",
    "elastic_mesh_shape",
    "StragglerMonitor",
]

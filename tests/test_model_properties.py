"""Property tests on model invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from proptest import given, settings, strategies as hst

from repro.configs import get_smoke_config
from repro.models import rope, transformer
from repro.roofline.analysis import (
    _shape_bytes,
    collective_bytes_from_hlo,
    weighted_collective_bytes,
)


# ---------------------------------------------------------------------------
# causality: tokens at position > i never affect logits at position i
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["olmo-1b", "mixtral-8x7b", "recurrentgemma-2b", "xlstm-1.3b"])
def test_causality(arch):
    cfg = get_smoke_config(arch)
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    b, s, cut = 1, 16, 8
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (b, s), 1, cfg.vocab_size)
    toks2 = toks.at[:, cut:].set((toks[:, cut:] + 7) % cfg.vocab_size)
    l1, _ = transformer.forward_train(cfg, params, {"tokens": toks})
    l2, _ = transformer.forward_train(cfg, params, {"tokens": toks2})
    np.testing.assert_allclose(
        np.asarray(l1[:, :cut], np.float32),
        np.asarray(l2[:, :cut], np.float32),
        rtol=1e-3, atol=1e-3,
    )


# ---------------------------------------------------------------------------
# incremental decoding == one-shot prefill
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm-360m", "recurrentgemma-2b"])
def test_prefill_then_decode_matches_longer_prefill(arch):
    cfg = get_smoke_config(arch)
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    b, s = 1, 10
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 1, cfg.vocab_size)

    # path A: prefill all s tokens
    ca = transformer.init_caches(cfg, b, s + 2)
    la, _ = transformer.prefill(cfg, params, {"tokens": toks}, ca)

    # path B: prefill s-1 then decode token s-1
    cb = transformer.init_caches(cfg, b, s + 2)
    _, cb = transformer.prefill(cfg, params, {"tokens": toks[:, :-1]}, cb)
    lb, _ = transformer.decode_step(
        cfg, params, {"tokens": toks[:, -1:]}, cb, jnp.asarray(s - 1, jnp.int32)
    )
    np.testing.assert_allclose(
        np.asarray(la, np.float32), np.asarray(lb, np.float32), rtol=0.1, atol=0.15
    )


# ---------------------------------------------------------------------------
# loss chunking is semantics-preserving (any divisor chunk)
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(chunk=hst.sampled_from([4, 8, 16, 32]))
def test_loss_chunk_invariance(chunk):
    cfg = get_smoke_config("olmo-1b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(3), (2, 32), 1, cfg.vocab_size),
        "targets": jax.random.randint(jax.random.PRNGKey(4), (2, 32), 1, cfg.vocab_size),
    }
    l0, _ = transformer.lm_loss(dataclasses.replace(cfg, loss_chunk=0), params, batch)
    l1, _ = transformer.lm_loss(dataclasses.replace(cfg, loss_chunk=chunk), params, batch)
    assert abs(float(l0) - float(l1)) < 1e-4


# ---------------------------------------------------------------------------
# RoPE: rotation preserves norms; relative-position property
# ---------------------------------------------------------------------------

def test_rope_preserves_norm():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 64))
    angles = rope.rope_angles(jnp.arange(8)[None], 64, 10_000.0)
    qr = rope.apply_rope(q, angles)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(qr), axis=-1),
        np.linalg.norm(np.asarray(q), axis=-1),
        rtol=1e-5,
    )


def test_rope_relative_position():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    h = 32
    q = jax.random.normal(jax.random.PRNGKey(0), (h,))
    k = jax.random.normal(jax.random.PRNGKey(1), (h,))

    def dot_at(i, j):
        a = rope.rope_angles(jnp.asarray([[i]]), h, 10_000.0)
        b = rope.rope_angles(jnp.asarray([[j]]), h, 10_000.0)
        qr = rope.apply_rope(q[None, None, None], a)[0, 0, 0]
        kr = rope.apply_rope(k[None, None, None], b)[0, 0, 0]
        return float(jnp.dot(qr, kr))

    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4
    assert abs(dot_at(7, 0) - dot_at(17, 10)) < 1e-4


def test_mrope_sections_cover_head_dim():
    cfg = get_smoke_config("qwen2-vl-72b")
    assert sum(cfg.mrope_sections) == cfg.head_dim // 2


# ---------------------------------------------------------------------------
# HLO collective parser (roofline input)
# ---------------------------------------------------------------------------

_FAKE_HLO = """
  %ag = bf16[16,512]{1,0} all-gather(%p0), replica_groups=...
  %ar = f32[4,4]{1,0} all-reduce(%x), to_apply=%add
  %ags = (bf16[8,8], bf16[8,8]) all-gather-start(%p1)
  %agd = bf16[64,64]{1,0} all-gather-done(%ags)
  %a2a = bf16[2,2]{1,0} all-to-all(%y)
  %cp = s32[10]{0} collective-permute(%z)
  %dot = f32[8,8]{1,0} dot(%a, %b)
"""


def test_collective_parser_classes_and_bytes():
    got = collective_bytes_from_hlo(_FAKE_HLO)
    assert got["all-gather"] == 16 * 512 * 2 + 64 * 64 * 2  # plain + done
    assert got["all-reduce"] == 4 * 4 * 4
    assert got["all-to-all"] == 2 * 2 * 2
    assert got["collective-permute"] == 10 * 4
    w = weighted_collective_bytes(got)
    assert w == got["all-gather"] + 2 * got["all-reduce"] + got["all-to-all"] + got["collective-permute"]


@given(hst.sampled_from(["f32[2,3]", "bf16[128]", "s8[4,4,4]", "pred[7]", "f32[]"]))
def test_shape_bytes_parser(tok):
    sizes = {"f32[2,3]": 24, "bf16[128]": 256, "s8[4,4,4]": 64, "pred[7]": 7, "f32[]": 4}
    assert _shape_bytes(tok) == sizes[tok]

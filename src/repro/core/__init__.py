"""Core abstractions: memory kinds, pass-by-reference offload, prefetch engines.

This package is the paper's contribution (Jamieson & Brown, JPDC 2020)
adapted to TPU memory hierarchies — see DESIGN.md §2.
"""
from repro.core import memkind
from repro.core.memkind import (
    ALL_DEVICE,
    DEVICE,
    DISK_HOST,
    DISK_OPT,
    DISK_PARAMS,
    HOST_ALL,
    HOST_OPT,
    HOST_PARAMS,
    PINNED_HOST,
    UNPINNED_HOST,
    MemKind,
    PlacementPolicy,
    all_kinds,
    get_policy,
    host_offload_supported,
    place,
    sharding_for,
)
from repro.core.spillstore import SpillStore, is_disk_leaf
from repro.core.engine import (
    AdaptiveDistance,
    EngineConfig,
    LinkModel,
    PAPER_EPIPHANY_LINK,
    TransferEngine,
)
from repro.core.kvpager import (
    KVPager,
    KVPagerConfig,
    PageStream,
    assemble_view,
    paged_cache_supported,
)
from repro.core.offload import offload
from repro.core.weightstream import (
    StreamUnit,
    WeightGroup,
    WeightStreamPlan,
    WeightStreamSupport,
    merge_expert_slice,
    weight_stream_support,
    weight_stream_supported,
)
from repro.core.prefetch import eager_transfer, fetch_chunk, stream_blocks, streamed_scan
from repro.core.refspec import AUTO, Access, OffloadRef, PrefetchSpec
from repro.core.hoststream import HostStreamExecutor, StreamStats
from repro.core.localcopy import LocalCopyCache

__all__ = [
    "memkind",
    "MemKind",
    "PlacementPolicy",
    "get_policy",
    "host_offload_supported",
    "place",
    "sharding_for",
    "DEVICE",
    "PINNED_HOST",
    "UNPINNED_HOST",
    "DISK_HOST",
    "ALL_DEVICE",
    "HOST_OPT",
    "HOST_PARAMS",
    "HOST_ALL",
    "DISK_OPT",
    "DISK_PARAMS",
    "all_kinds",
    "SpillStore",
    "is_disk_leaf",
    "offload",
    "OffloadRef",
    "PrefetchSpec",
    "Access",
    "AUTO",
    "TransferEngine",
    "EngineConfig",
    "AdaptiveDistance",
    "LinkModel",
    "PAPER_EPIPHANY_LINK",
    "streamed_scan",
    "stream_blocks",
    "fetch_chunk",
    "eager_transfer",
    "HostStreamExecutor",
    "StreamStats",
    "LocalCopyCache",
    "KVPager",
    "KVPagerConfig",
    "PageStream",
    "assemble_view",
    "paged_cache_supported",
    "StreamUnit",
    "WeightGroup",
    "WeightStreamPlan",
    "WeightStreamSupport",
    "merge_expert_slice",
    "weight_stream_support",
    "weight_stream_supported",
]

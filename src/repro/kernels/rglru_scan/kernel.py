"""Chunked linear-recurrence scan: the RG-LRU hot-spot, TPU-native.

The recurrence ``h_t = a_t h_{t-1} + b_t`` is elementwise over the channel
dim and sequential over time — exactly the memory-hierarchy shape the paper
targets: the (B, S, W) gate tensors live in HBM, and only a
``(chunk_t, block_w)`` tile is ever resident in VMEM.  The time axis is the
*innermost* grid dim with "arbitrary" semantics, so the carried state
``h`` persists in a VMEM scratch across time chunks while Mosaic
double-buffers the chunk loads (the implicit prefetch pipeline — the
paper's ``distance=1``).

Versus ``lax.associative_scan`` (the XLA path): the associative scan is
O(log S) depth but materializes O(S) intermediates per level in HBM;
the chunked kernel makes one pass, fully sequential in VMEM, and
parallelizes over (B, W) — the natural TPU mapping because B·W/block_w
grid cells keep the VPU busy while S streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.jaxcompat import tpu_compiler_params


def _lru_kernel(a_ref, b_ref, o_ref, h_ref, *, chunk_t: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[0]  # (chunk_t, block_w)
    b = b_ref[0]

    def step(i, h):
        h = a[i] * h + b[i]
        o_ref[0, i, :] = h
        return h

    h_ref[0] = jax.lax.fori_loop(0, chunk_t, step, h_ref[0])


def linear_recurrence_p(
    a: jax.Array,  # (B, S, W) f32
    b: jax.Array,
    *,
    chunk_t: int,
    block_w: int,
    interpret: bool,
) -> jax.Array:
    bsz, s, w = a.shape
    assert s % chunk_t == 0 and w % block_w == 0, (a.shape, chunk_t, block_w)
    kernel = functools.partial(_lru_kernel, chunk_t=chunk_t)
    return pl.pallas_call(
        kernel,
        grid=(bsz, w // block_w, s // chunk_t),
        in_specs=[
            pl.BlockSpec((1, chunk_t, block_w), lambda i, j, t: (i, t, j)),
            pl.BlockSpec((1, chunk_t, block_w), lambda i, j, t: (i, t, j)),
        ],
        out_specs=pl.BlockSpec((1, chunk_t, block_w), lambda i, j, t: (i, t, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), a.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_w), a.dtype)],
        interpret=interpret,
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
    )(a, b)

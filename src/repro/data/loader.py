"""Host-side prefetching data loader — the paper's prefetch at the input level.

Batches are produced on the host (the paper's ``Host`` memory kind: a level
the accelerator cannot address) and transferred with a bounded look-ahead of
``distance`` batches, so H2D input copies overlap the previous step's compute.
``distance=0`` is the paper's on-demand mode (the step stalls on its input).

:class:`DiskShardLoader` extends the same pattern one level down the
hierarchy: batches live as chunk files in a
:class:`~repro.core.spillstore.SpillStore` (the ``DiskHost`` tier) and are
served as memory-mapped views, so a disk-resident dataset streams to the
device without ever materializing in host RAM — the bytes are read only
when the H2D copy touches them, at most one look-ahead window at a time.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Callable, Optional

import jax

Pytree = Any


class PrefetchLoader:
    def __init__(
        self,
        make_batch: Callable[[int], Pytree],
        *,
        shardings: Optional[Pytree] = None,
        distance: int = 2,
        start_step: int = 0,
    ) -> None:
        self._make = make_batch
        self._sh = shardings
        self._distance = max(distance, 0)
        self._next = start_step
        self._ring: deque[tuple[int, Pytree]] = deque()

    def _put(self, step: int) -> Pytree:
        batch = self._make(step)
        if self._sh is not None:
            batch = jax.device_put(batch, self._sh)
        else:
            batch = jax.device_put(batch)
        return batch

    def __call__(self, step: int) -> Pytree:
        """Batch for ``step``; issues transfers up to ``step + distance``."""
        # drop stale entries (restart / out-of-order resume)
        while self._ring and self._ring[0][0] < step:
            self._ring.popleft()
        if not self._ring or self._ring[0][0] != step:
            self._ring.clear()
            self._next = step
        while self._next <= step + self._distance:
            self._ring.append((self._next, self._put(self._next)))
            self._next += 1
        s, batch = self._ring.popleft()
        assert s == step
        return batch


class DiskShardLoader:
    """``make_batch`` over disk-resident shards (the ``DiskHost`` data tier).

    ``write_shards`` spills batches into the store once (e.g. a dataset
    conversion job); ``__call__(step)`` then returns the shard for ``step``
    as a memory-mapped pytree — zero host-RAM cost until the transfer
    engine or ``device_put`` reads the bytes.  Wrap in
    :class:`PrefetchLoader` for look-ahead exactly like a RAM loader::

        loader = PrefetchLoader(DiskShardLoader(store, n_shards), distance=2)
    """

    _KEY = "shard_{:06d}"

    def __init__(self, store, n_shards: int, *, template: Optional[Pytree] = None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        self._store = store
        self._n = n_shards
        self._template = template

    @classmethod
    def write_shards(
        cls, store, make_batch: Callable[[int], Pytree], n_shards: int
    ) -> "DiskShardLoader":
        """Spill ``n_shards`` batches into ``store`` and return a loader
        over them (one chunk file per shard: one disk request each)."""
        for i in range(n_shards):
            store.put(cls._KEY.format(i), make_batch(i))
        return cls(store, n_shards)

    def __call__(self, step: int) -> Pytree:
        key = self._KEY.format(step % self._n)
        return self._store.get(key, template=self._template)

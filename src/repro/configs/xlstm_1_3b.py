"""xLSTM-1.3B [arXiv:2405.04517; unverified].

48 blocks d_model=2048 4H vocab=50304, d_ff=0 (no separate FFN — xLSTM blocks
carry their own up/down projections, proj_factor 2, qk at half width).
Pattern: one sLSTM block every 8 (xLSTM[7:1]); the rest mLSTM with
chunkwise-parallel training.  O(1) decode state: runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50_304,
    mlp_type="none",
    norm_type="layernorm",
    pos_type="none",
    slstm_every=8,
    proj_factor=2.0,
    mlstm_chunk=128,
    conv_width=4,
    tie_embeddings=True,
    use_scan=True,  # period-scan over (7x mLSTM + sLSTM) groups
    source="arXiv:2405.04517; unverified",
)


def smoke() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        vocab_size=256, slstm_every=3, mlstm_chunk=16, remat="none",
    )

"""Paper §3.3 memory-model tests: local-copy preference, write-through,
pool eviction."""
import jax.numpy as jnp
import numpy as np

from repro.core.localcopy import LocalCopyCache


def test_read_prefers_local_copy():
    """paper: `tmp = a; a = tmp * a` — second access hits the local copy."""
    c = LocalCopyCache()
    c.register("a", np.arange(8.0, dtype=np.float32))
    tmp = c.read("a")
    again = c.read("a")
    assert c.stats == {"hits": 1, "misses": 1, "evictions": 0, "writebacks": 0}
    np.testing.assert_array_equal(np.asarray(tmp), np.asarray(again))


def test_write_through_updates_home_and_local():
    c = LocalCopyCache()
    c.register("a", np.ones(4, np.float32))
    a = c.read("a")
    c.write("a", a * 3.0)
    # home updated (write-through) ...
    np.testing.assert_array_equal(c.home("a"), np.full(4, 3.0, np.float32))
    # ... and subsequent reads hit the updated local copy
    np.testing.assert_array_equal(np.asarray(c.read("a")), np.full(4, 3.0, np.float32))
    assert c.stats["writebacks"] == 1
    assert c.stats["misses"] == 1  # the write did not invalidate


def test_capacity_eviction_like_central_pool():
    """paper: 'locally held copies of data elsewhere ... are freed'."""
    c = LocalCopyCache(capacity_bytes=3 * 16 * 4)  # 3 x (16 f32)
    for i in range(5):
        c.register(f"v{i}", np.full(16, float(i), np.float32))
        c.read(f"v{i}")
    assert c.stats["evictions"] >= 2
    # evicted entries re-fetch from home, values intact
    v0 = c.read("v0")
    np.testing.assert_array_equal(np.asarray(v0), np.zeros(16, np.float32))


def test_invalidate_forces_refetch():
    c = LocalCopyCache()
    c.register("a", np.zeros(4, np.float32))
    c.read("a")
    c.invalidate("a")
    c.read("a")
    assert c.stats["misses"] == 2

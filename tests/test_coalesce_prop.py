"""Property-based coalescing round-trip tests (via the proptest grid shim).

``tests/test_engine.py`` covers hand-picked layouts; this suite sweeps the
pack -> device_put -> bitcast-unpack round trip over the property space the
engine actually sees in training: mixed dtypes (bf16, f32, i32,
f64-canonicalized), odd and zero-length shapes, deep pytrees, and
disk-tier (spill store) sources — asserting bitwise equality with the
per-leaf ``jax.device_put`` reference in every cell.
"""
import jax
import jax.numpy as jnp
import numpy as np

from proptest import given, settings, strategies as hst

from repro.core.engine import GroupLayout, TransferEngine
from repro.core.spillstore import SpillStore

#: dtype menu: extension (bf16), native, integer, and canonicalized-wide
_DTYPES = ["bfloat16", "float32", "int32", "float64"]


def _make_leaf(rng, n, dtype_name):
    a = rng.standard_normal((max(n, 0),))
    if dtype_name == "bfloat16":
        return np.asarray(jnp.asarray(a, jnp.bfloat16))
    if dtype_name in ("int32",):
        return (a * 100).astype(dtype_name)
    return a.astype(dtype_name)


def _roundtrip_equals_device_put(group):
    """pack -> H2D -> unpack must equal per-leaf device_put, bitwise."""
    leaves = jax.tree.leaves(group)
    layout = GroupLayout(group)
    staging = layout.new_staging()
    layout.pack_into(leaves, staging)
    flat = jax.device_put(staging)
    out = layout.unpack(flat, leaves)
    for got, src in zip(jax.tree.leaves(out), leaves):
        ref = jax.device_put(src)  # the canonicalizing per-leaf reference
        got, ref = np.asarray(got), np.asarray(ref)
        assert got.dtype == ref.dtype
        assert got.shape == ref.shape
        np.testing.assert_array_equal(got, ref)


@settings(max_examples=40, deadline=None)
@given(
    n=hst.integers(min_value=0, max_value=19),
    dtype_idx=hst.integers(min_value=0, max_value=len(_DTYPES) - 1),
)
def test_single_leaf_roundtrip(n, dtype_idx):
    """Every (length, dtype) cell — including zero-length and odd lengths
    that leave unaligned tails inside the 64B-padded staging buffer."""
    rng = np.random.default_rng(n * 31 + dtype_idx)
    _roundtrip_equals_device_put({"x": _make_leaf(rng, n, _DTYPES[dtype_idx])})


@settings(max_examples=30, deadline=None)
@given(
    depth=hst.integers(min_value=1, max_value=4),
    seed=hst.integers(min_value=0, max_value=3),
)
def test_deep_mixed_pytree_roundtrip(depth, seed):
    """Nested dict/tuple/list pytrees with one leaf of every dtype per
    level, lengths varying per level (incl. an empty leaf)."""
    rng = np.random.default_rng(seed)
    tree = {"empty": _make_leaf(rng, 0, "float32")}
    node = tree
    for lvl in range(depth):
        leaves = tuple(
            _make_leaf(rng, 2 * lvl + i + 1, dt) for i, dt in enumerate(_DTYPES)
        )
        node["child"] = {"leaves": leaves, "l": [leaves[0], leaves[-1]]}
        node = node["child"]
    _roundtrip_equals_device_put(tree)


@settings(max_examples=20, deadline=None)
@given(
    n=hst.integers(min_value=1, max_value=9),
    dtype_idx=hst.integers(min_value=0, max_value=len(_DTYPES) - 1),
)
def test_mixed_device_host_passthrough(n, dtype_idx):
    """Device-resident leaves interleaved with host leaves: the device
    leaves pass by reference, the host leaves round-trip bitwise."""
    rng = np.random.default_rng(n * 7 + dtype_idx)
    dev = jnp.arange(float(n))
    group = {
        "host": _make_leaf(rng, n, _DTYPES[dtype_idx]),
        "dev": dev,
        "host2": _make_leaf(rng, 2 * n + 1, "float32"),
    }
    leaves = jax.tree.leaves(group)
    layout = GroupLayout(group)
    staging = layout.new_staging()
    layout.pack_into(leaves, staging)
    out = layout.unpack(jax.device_put(staging), leaves)
    assert out["dev"] is dev
    np.testing.assert_array_equal(
        np.asarray(out["host"]), np.asarray(jax.device_put(group["host"]))
    )
    np.testing.assert_array_equal(np.asarray(out["host2"]), group["host2"])


@settings(max_examples=12, deadline=None)
@given(
    n=hst.integers(min_value=0, max_value=11),
    dtype_idx=hst.integers(min_value=0, max_value=len(_DTYPES) - 1),
)
def test_disk_tier_roundtrip_through_engine(n, dtype_idx, tmp_path_factory=None):
    """Full engine path for spill-store (DiskHost) groups: disk -> host
    staging -> pack -> device must equal device_put of the original."""
    import tempfile

    rng = np.random.default_rng(n * 13 + dtype_idx)
    group = {
        "a": _make_leaf(rng, n, _DTYPES[dtype_idx]),
        "b": _make_leaf(rng, n + 3, "float32"),
    }
    with tempfile.TemporaryDirectory() as d:
        store = SpillStore(d)
        store.put("g", group)
        disk_group = store.get("g")
        with TransferEngine() as eng:
            fut = eng.submit_group(0, disk_group)
            fut.wait()
            staged = fut.group()
        for got, src in zip(jax.tree.leaves(staged), jax.tree.leaves(group)):
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(jax.device_put(src))
            )

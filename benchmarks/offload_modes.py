"""Paper Fig 3 analogue: ML benchmark under eager / on-demand / prefetch.

The paper's claim structure this reproduces:
  * on-demand  <<  eager  <=  prefetch   (end-to-end phase times)
  * the on-demand penalty comes from *request count*, not per-transfer time
  * model update is unaffected by the transfer mode (no data movement)

The images live at the paper's ``Host`` kind (outside the device step — on
this CPU container host-kind placement is the host numpy heap; on TPU it is
``pinned_host``); the kernel receives them **by reference** and the
HostStreamExecutor moves pieces according to the schedule.  Chunk sizes
mirror the paper: on-demand fetches one image row-group at a time; prefetch
streams ``distance`` groups ahead.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.core.hoststream import HostStreamExecutor, StreamStats
from repro.core.refspec import PrefetchSpec

import jax
import jax.numpy as jnp


def run(n_pixels: int = 3600, *, groups: int = 16, batch_images: int = 8, tag: str = "fig3_small") -> list[dict]:
    cfg = C.LungNNConfig(n_pixels=n_pixels, batch_images=batch_images)
    params = C.init_lung_nn(cfg)
    xs, ys = C.make_images(cfg, batch_images)
    xs_host = np.asarray(xs)  # paper Host kind: accelerator can't address this
    ys_dev = jnp.asarray(ys)

    # split the pixel dimension into groups: each group is one "transfer"
    assert n_pixels % groups == 0
    gp = n_pixels // groups
    w1_groups = [np.asarray(params["w1"][i * gp : (i + 1) * gp]) for i in range(groups)]
    x_groups = [xs_host[:, i * gp : (i + 1) * gp] for i in range(groups)]

    # phase 1: feed forward = accumulate x_g @ w1_g over groups, then head
    @jax.jit
    def ff_apply(carry, group):
        xg, wg = group
        return carry + xg @ wg

    # phase 2: combine gradients — per-group grad of the first layer
    @jax.jit
    def grad_apply(carry, group):
        xg, wg, dh = group  # dh: (B, hidden) upstream grad (precomputed)
        gw = xg.T @ dh
        return carry + jnp.sum(gw * wg), gw  # writeback group grads

    # upstream pieces computed once on device (not part of the transfer study)
    h = jax.nn.sigmoid(xs @ params["w1"])
    p = jax.nn.sigmoid(h @ params["w2"])
    dh = ((p - ys_dev) @ params["w2"].T) * h * (1 - h)

    rows = []
    for mode in ("eager", "on_demand", "prefetch"):
        spec = PrefetchSpec(buffer_size=4, elements_per_fetch=1, distance=2)

        # -- feed forward ----------------------------------------------------
        ex = HostStreamExecutor(ff_apply)
        st = StreamStats()
        carry = jnp.zeros((batch_images, cfg.n_hidden), jnp.float32)
        t = C.timed(
            lambda: ex.run(
                carry, list(zip(x_groups, w1_groups)), prefetch=spec, mode=mode, stats=st
            )[0],
            stats=st,
        )
        ff_s = t["median_s"]
        ex.close()

        # -- combine gradients (rw: grads written back to host) ---------------
        ex2 = HostStreamExecutor(grad_apply, writeback=True)
        st2 = StreamStats()
        t2 = C.timed(
            lambda: ex2.run(
                jnp.zeros(()), list(zip(x_groups, w1_groups, [dh] * groups)),
                prefetch=spec, mode=mode, stats=st2,
            )[0],
            stats=st2,
        )
        cg_s = t2["median_s"]
        ex2.close()

        # -- model update (no transfers — paper: identical across modes) ------
        grads = C.combine_gradients(params, xs, ys)
        upd = jax.jit(C.model_update)
        mu_s = C.timed(lambda: upd(params, grads))["median_s"]

        # per-run numbers: stats were reset after warmup, so the counters
        # cover exactly st.n_runs timed repeats (no repeat-count guessing)
        per = max(st.n_runs, 1)
        rows.append(
            {
                "mode": mode,
                "feed_forward_s": ff_s,
                "combine_grad_s": cg_s,
                "model_update_s": mu_s,
                "n_transfers": st.n_transfers // per,
                "bytes_h2d": st.bytes_h2d // per,
                "h2d_requests": st.h2d_requests // per,
                "requests_per_group": st.requests_per_group,
                "transfer_wait_s": st.transfer_wait_s / per,
                "compute_s": st.compute_s / per,
                "n_runs": st.n_runs,
            }
        )
    C.print_table(f"paper Fig3 analogue ({tag}, {n_pixels} px) — measured on CPU",
                  rows,
                  ["mode", "feed_forward_s", "combine_grad_s", "model_update_s", "n_transfers"])
    C.save_rows(tag, rows)
    modeled = modeled_link_rows(rows, n_pixels, batch_images)
    C.print_table(
        f"paper-link model ({tag}): Epiphany 88 MB/s + 0.104 ms/request "
        f"(paper's measured constants) applied to the RECORDED schedule",
        modeled, ["mode", "n_requests", "transfer_busy_s", "total_s", "vs_prefetch"])
    C.save_rows(tag + "_modeled", modeled)
    return rows


# paper-measured link constants (§5.1): Epiphany observed 88 MB/s; host
# service latency ~0.104 ms/request (Table 2, 128B mean)
PAPER_BW = 88e6
PAPER_LAT = 0.104e-3


def modeled_link_rows(rows: list[dict], n_pixels: int, batch_images: int) -> list[dict]:
    """Apply the paper's link to the recorded transfer schedule.

    The measured CPU rows above share one flaw as a reproduction: this
    container's host->device 'link' is main memory (GB/s, ~us latency), so
    the 21-25x on-demand penalty the paper measures over a ~100 MB/s board
    link cannot physically appear.  The *schedule* (how many requests, how
    many bytes, what overlaps) is real and recorded; this table replays it
    against the paper's own measured constants.  on_demand_element is the
    paper's true on-demand mode: one request per element.
    """
    by = {r["mode"]: r for r in rows}
    # rows carry exact per-run counters (see run(): stats reset after warmup)
    bytes_total = by["prefetch"]["bytes_h2d"]
    compute = by["eager"]["compute_s"]
    n_groups = by["prefetch"]["n_transfers"]
    n_requests_chunked = by["prefetch"]["h2d_requests"]
    n_elements = n_pixels * batch_images
    out = []

    def total(n_req, overlap):
        busy = n_req * PAPER_LAT + bytes_total / PAPER_BW
        t = max(busy, compute) if overlap else busy + compute
        return busy, t

    for mode, n_req, overlap in (
        ("eager", 2, False),  # bulk copy, then compute
        ("on_demand_element", n_elements, False),  # paper's per-element fetch
        ("on_demand_chunk", n_groups, False),  # one request per group (seed)
        ("prefetch", n_requests_chunked, True),  # the engine's recorded count
    ):
        busy, t = total(n_req, overlap)
        out.append({"mode": mode, "n_requests": int(n_req),
                    "transfer_busy_s": busy, "total_s": t})
    ref = next(r for r in out if r["mode"] == "prefetch")["total_s"]
    for r in out:
        r["vs_prefetch"] = r["total_s"] / ref
    return out


def main() -> int:
    rows = run(3600, groups=16, tag="fig3_small")
    modeled = {r["mode"]: r for r in modeled_link_rows(rows, 3600, 8)}
    ok_order = (
        modeled["prefetch"]["total_s"]
        <= modeled["eager"]["total_s"]
        <= modeled["on_demand_element"]["total_s"]
    )
    ratio = modeled["on_demand_element"]["total_s"] / modeled["prefetch"]["total_s"]
    print(
        f"claim checks (paper-link model): prefetch <= eager <= on-demand: {ok_order}; "
        f"on-demand(element)/prefetch = {ratio:.0f}x (paper: 21-25x on Epiphany)"
    )
    return 0 if ok_order and ratio > 5 else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Core abstractions: memory kinds, pass-by-reference offload, prefetch engines.

This package is the paper's contribution (Jamieson & Brown, JPDC 2020)
adapted to TPU memory hierarchies — see DESIGN.md §2.
"""
from repro.core import memkind
from repro.core.memkind import (
    ALL_DEVICE,
    DEVICE,
    HOST_ALL,
    HOST_OPT,
    HOST_PARAMS,
    PINNED_HOST,
    UNPINNED_HOST,
    MemKind,
    PlacementPolicy,
    get_policy,
    host_offload_supported,
    place,
    sharding_for,
)
from repro.core.engine import (
    AdaptiveDistance,
    EngineConfig,
    LinkModel,
    PAPER_EPIPHANY_LINK,
    TransferEngine,
)
from repro.core.offload import offload
from repro.core.prefetch import eager_transfer, fetch_chunk, stream_blocks, streamed_scan
from repro.core.refspec import AUTO, Access, OffloadRef, PrefetchSpec
from repro.core.hoststream import HostStreamExecutor, StreamStats
from repro.core.localcopy import LocalCopyCache

__all__ = [
    "memkind",
    "MemKind",
    "PlacementPolicy",
    "get_policy",
    "host_offload_supported",
    "place",
    "sharding_for",
    "DEVICE",
    "PINNED_HOST",
    "UNPINNED_HOST",
    "ALL_DEVICE",
    "HOST_OPT",
    "HOST_PARAMS",
    "HOST_ALL",
    "offload",
    "OffloadRef",
    "PrefetchSpec",
    "Access",
    "AUTO",
    "TransferEngine",
    "EngineConfig",
    "AdaptiveDistance",
    "LinkModel",
    "PAPER_EPIPHANY_LINK",
    "streamed_scan",
    "stream_blocks",
    "fetch_chunk",
    "eager_transfer",
    "HostStreamExecutor",
    "StreamStats",
    "LocalCopyCache",
]

"""Three-level streaming study: the DiskHost tier under modeled links.

The paper hides host latency behind compute with prefetch (§5.1); the
``DiskHost`` tier repeats the trick one level down — disk fetches overlap
behind host->device transfers.  This suite streams spill-store groups
through the engine's two-stage pipeline under *two* modeled links (a host
link and a slower, higher-latency disk link — same ``LinkModel``, second
instance) and records, per schedule:

  * requests/group per tier (coalescing: 1 H2D + 1 disk chunk per group),
  * the stall breakdown: compute-thread wait (compute-on-H2D), the
    transfer worker's disk wait (H2D-on-disk), and writeback drain,
  * steady-state tail waits for ``distance=1`` vs ``distance="auto"``.

Emits ``results/bench/BENCH_disk.json``.  Pass gates (the tentpole
acceptance): both tiers coalesce to 1 request/group, and at
``distance="auto"`` the adaptive window hides the disk latency — the
steady-state compute wait drops well below the ``distance=1`` schedule's
and below the serial disk occupancy it would pay unoverlapped.

``REPRO_BENCH_SMOKE=1`` (set by ``benchmarks/run.py --smoke``) shrinks the
workload for CI.
"""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core.engine import EngineConfig, LinkModel
from repro.core.hoststream import HostStreamExecutor, StreamStats
from repro.core.refspec import AUTO, PrefetchSpec
from repro.core.spillstore import SpillStore

SMOKE = bool(int(os.environ.get("REPRO_BENCH_SMOKE", "0")))

N_GROUPS = 12 if SMOKE else 24
REPEATS = 2 if SMOKE else 5
GROUP_SHAPE = (64, 64)  # 16 KB f32 per leaf

#: the host link: the paper's request-cost regime, no latency tail
HOST_LINK = LinkModel(request_s=0.1e-3, bandwidth_Bps=500e6, latency_s=0.0)
#: the disk link: slower per request and high *latency* — the overlappable
#: term the disk read-ahead window hides (bandwidth deliberately >= the
#: host link's so the pipeline is latency-bound, not throughput-bound)
DISK_LINK = LinkModel(request_s=0.3e-3, bandwidth_Bps=500e6, latency_s=4e-3)


def _workload(tmpdir: str):
    rng = np.random.default_rng(0)
    host_groups = [
        {"w": rng.standard_normal(GROUP_SHAPE).astype(np.float32),
         "b": rng.standard_normal((GROUP_SHAPE[1],)).astype(np.float32)}
        for _ in range(N_GROUPS)
    ]
    store = SpillStore(tmpdir)
    disk_groups = []
    for i, g in enumerate(host_groups):
        store.put(f"g{i:04d}", g)
        disk_groups.append(store.get(f"g{i:04d}"))

    @jax.jit
    def apply_ro(carry, g):
        return carry + jnp.sum(g["w"] @ g["w"].T) + jnp.sum(g["b"])

    @jax.jit
    def apply_rw(carry, g):
        return carry + jnp.sum(g["b"]), {"w": g["w"] * 1.0001, "b": g["b"]}

    return host_groups, disk_groups, apply_ro, apply_rw


def _tail(xs, frac=0.5):
    xs = list(xs)
    return sum(xs[int(len(xs) * frac):])


def _row(name, source, distance, st: StreamStats, t: dict) -> dict:
    per = max(st.n_runs, 1)
    return {
        "schedule": name,
        "source": source,
        "distance": str(distance),
        "total_s": t["median_s"],
        "total_min_s": t["min_s"],
        "requests_per_group": st.requests_per_group,
        "disk_requests_per_group": st.disk_requests_per_group,
        "per_tier": st.per_tier(),
        "stall_breakdown": {
            "compute_on_h2d_s": st.transfer_wait_s / per,
            "h2d_on_disk_s": st.disk_wait_s / per,
            "writeback_drain_s": st.writeback_drain_s / per,
        },
        "tail_wait_s": _tail(st.wait_per_group) / per,
        "tail_disk_wait_s": _tail(st.disk_wait_per_group) / per,
        "final_distance": (
            st.distance_trace[-1] if st.distance_trace else None
        ),
        "wait_hist": st.wait_hist(),
    }


def run(tag: str = "BENCH_disk") -> list[dict]:
    rows = []
    with tempfile.TemporaryDirectory(prefix="repro-bench-disk-") as td:
        host_groups, disk_groups, apply_ro, apply_rw = _workload(td)
        cfg = EngineConfig(link=HOST_LINK, disk_link=DISK_LINK)
        values = {}

        # -- ro streaming: host-tier baseline + disk tier at d=1 / auto -----
        cases = [
            ("host", host_groups, 1),
            ("disk", disk_groups, 1),
            ("disk", disk_groups, AUTO),
        ]
        for source, groups, dist in cases:
            spec = PrefetchSpec(buffer_size=N_GROUPS + 2, distance=dist)
            with HostStreamExecutor(apply_ro, engine_config=cfg) as ex:
                st = StreamStats()
                t = C.timed(
                    lambda: ex.run(
                        jnp.zeros(()), groups, mode="prefetch",
                        prefetch=spec, stats=st,
                    )[0],
                    stats=st, repeats=REPEATS,
                )
                out, _ = ex.run(jnp.zeros(()), groups, mode="prefetch", prefetch=spec)
            values[(source, str(dist))] = float(out)
            rows.append(_row("ro", source, dist, st, t))

        # -- rw streaming (moments-style writeback) from disk at auto -------
        spec = PrefetchSpec(buffer_size=N_GROUPS + 2, distance=AUTO)
        with HostStreamExecutor(apply_rw, writeback=True, engine_config=cfg) as ex:
            st = StreamStats()
            t = C.timed(
                lambda: ex.run(
                    jnp.zeros(()), disk_groups, mode="prefetch",
                    prefetch=spec, stats=st,
                )[0],
                stats=st, repeats=REPEATS,
            )
        rows.append(_row("rw", "disk", AUTO, st, t))

    # schedules never change values: disk == host, d=1 == auto, bitwise
    assert values[("disk", "1")] == values[("host", "1")] == values[("disk", AUTO)]

    C.print_table(
        "DiskHost three-level streaming (modeled host + disk links)",
        rows,
        ["schedule", "source", "distance", "total_s", "requests_per_group",
         "disk_requests_per_group", "tail_wait_s", "tail_disk_wait_s",
         "final_distance"],
    )
    C.save_rows(tag, rows)
    return rows


def main() -> int:
    rows = run()
    by = {(r["schedule"], r["source"], r["distance"]): r for r in rows}
    d1 = by[("ro", "disk", "1")]
    auto = by[("ro", "disk", str(AUTO))]
    rw = by[("rw", "disk", str(AUTO))]

    one_req = all(
        r["requests_per_group"] == 1.0 for r in (d1, auto, rw)
    ) and all(r["disk_requests_per_group"] == 1.0 for r in (d1, auto, rw))

    # the adaptive window must hide the disk latency: the steady-state
    # compute wait collapses vs the distance=1 schedule, and vs the serial
    # per-group disk cost (occupancy + latency) it would pay unoverlapped
    group_bytes = 4 * (GROUP_SHAPE[0] * GROUP_SHAPE[1] + GROUP_SHAPE[1])
    serial_disk_s = DISK_LINK.transfer_s(1, group_bytes) * (N_GROUPS // 2)
    hides_latency = (
        auto["tail_wait_s"] < 0.5 * d1["tail_wait_s"]
        and auto["tail_wait_s"] < 0.5 * serial_disk_s
    )
    grew = (auto["final_distance"] or 0) > 1

    print(
        f"requests/group: h2d {auto['requests_per_group']:.0f}, "
        f"disk {auto['disk_requests_per_group']:.0f} (gate: 1 each); "
        f"steady tail wait: auto {auto['tail_wait_s']*1e3:.2f} ms vs "
        f"d=1 {d1['tail_wait_s']*1e3:.2f} ms vs serial disk "
        f"{serial_disk_s*1e3:.2f} ms (gate: auto < 50% of both); "
        f"final distance {auto['final_distance']} (gate: > 1)"
    )
    return 0 if (one_req and hides_latency and grew) else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Dry-run machinery integration: lower+compile on a small fake mesh.

Runs in a subprocess because the device-count override must precede JAX
init (the real dry-run uses 512 devices; 8 suffice to exercise the sharding
rules, the sharder, and the roofline extraction end to end).
"""
import json
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax
from repro.configs import get_smoke_config
from repro.launch import dryrun as dr
from repro.roofline import analysis as ra

from repro.jaxcompat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
out = {}
for arch, shape in (("olmo-1b", "train_4k"), ("olmo-1b", "decode_32k"),
                    ("mixtral-8x7b", "train_4k")):
    cfg = dataclasses.replace(get_smoke_config(arch), n_layers=2)
    compiled, kind, _ = dr.lower_cell(cfg, shape, mesh)
    hlo = compiled.as_text()
    coll = ra.collective_bytes_from_hlo(hlo)
    ca = ra.cost_terms(compiled)
    out[f"{arch}:{shape}"] = {
        "kind": kind,
        "flops": ca["flops"],
        "has_collectives": any(v > 0 for v in coll.values()),
        "mem_gib": compiled.memory_analysis().temp_size_in_bytes / 2**30,
    }
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_dryrun_small_mesh_end_to_end():
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT],
        capture_output=True,
        text=True,
        timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu",
             "HOME": "/root"},
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][0]
    res = json.loads(line[len("RESULT "):])
    assert res["olmo-1b:train_4k"]["kind"] == "train"
    assert res["olmo-1b:decode_32k"]["kind"] == "decode"
    for k, v in res.items():
        assert v["flops"] > 0, k
        assert v["has_collectives"], k  # sharded programs must communicate

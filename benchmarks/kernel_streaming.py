"""Kernel-level prefetch study: streamed matmul DMA schedule (TPU-native).

The in-kernel analogue of the paper's §3.1 knobs: the weight operand stays
in HBM and is DMA'd through a VMEM ring.  On this CPU container the kernel
runs in interpret mode, so wall-clock is NOT the metric — the recorded
schedule statistics are: number of DMA issues, bytes per issue, ring
occupancy, and the (distance=0) on-demand stall structure.  On TPU hardware
the same sweep measures real overlap.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.core.engine import EngineConfig, LinkModel
from repro.core.hoststream import HostStreamExecutor, StreamStats
from repro.core.refspec import AUTO, PrefetchSpec
from repro.kernels.streamed_matmul import matmul_ref, streamed_matmul

#: emulated link for the host-stream sweep: modest occupancy, a 2 ms
#: completion latency — the term prefetch depth exists to hide.  distance=1
#: cannot cover it; the adaptive controller must find the window that does.
SWEEP_LINK = LinkModel(request_s=0.104e-3, bandwidth_Bps=2e9, latency_s=2e-3)


def host_stream_sweep() -> list[dict]:
    """The same K-tile schedule at the host level: weight tiles stream
    through the TransferEngine while the jitted tile-matmul computes.
    Sweeps fixed distances vs ``distance="auto"``; every setting must be
    numerically identical to eager."""
    m = n = 256
    k = 2048
    bk = 128
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    w_host = np.asarray(
        jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    )
    x_host = np.asarray(x)
    n_tiles = k // bk
    groups = [
        (x_host[:, i * bk : (i + 1) * bk], w_host[i * bk : (i + 1) * bk])
        for i in range(n_tiles)
    ]

    @jax.jit
    def apply(carry, g):
        xt, wt = g
        return carry + xt @ wt

    rows = []
    ref = None
    for dist in ("eager", 1, 2, 4, AUTO):
        with HostStreamExecutor(
            apply, engine_config=EngineConfig(link=SWEEP_LINK, max_distance=8)
        ) as ex:
            mode = "eager" if dist == "eager" else "prefetch"
            spec = None if dist == "eager" else PrefetchSpec(
                buffer_size=10, elements_per_fetch=1, distance=dist
            )
            # one warm run (compile), then best of two measured runs (the
            # container is shared: a noisy run would mis-rank the schedules)
            ex.run(jnp.zeros((m, n)), groups, mode=mode, prefetch=spec)
            best = None
            for _ in range(2):
                st = StreamStats()
                out, _ = ex.run(
                    jnp.zeros((m, n)), groups, mode=mode, prefetch=spec, stats=st
                )
                if best is None or st.transfer_wait_s < best.transfer_wait_s:
                    best = st
            st = best
        out = np.asarray(out)
        if ref is None:
            ref = out
        tail = list(st.wait_per_group)[n_tiles // 2 :]
        rows.append(
            {
                "distance": dist,
                "transfer_wait_s": st.transfer_wait_s,
                "steady_wait_s": float(sum(tail)),
                "final_distance": st.distance_trace[-1] if st.distance_trace else None,
                "requests_per_group": st.requests_per_group,
                "matches_eager": bool(np.array_equal(out, ref)),
            }
        )
    C.print_table(
        "host-stream K-tile schedule: fixed vs adaptive prefetch distance "
        "(emulated 2 ms-latency link)",
        rows,
        ["distance", "transfer_wait_s", "steady_wait_s", "final_distance",
         "matches_eager"],
    )
    C.save_rows("kernel_streaming_host", rows)
    return rows


def main() -> int:
    m = k = n = 512
    bk = 128
    x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    ref = matmul_ref(x, w)
    n_tiles_k = k // bk
    n_tiles = (m // 128) * (n // 128) * n_tiles_k
    rows = []
    for dist, slots in [(0, 1), (1, 2), (2, 3), (4, 5)]:
        spec = PrefetchSpec(buffer_size=slots, elements_per_fetch=1, distance=dist)
        out = streamed_matmul(x, w, spec=spec, block_k=bk)
        ok = bool(jnp.allclose(out, ref, atol=1e-3))
        rows.append(
            {
                "distance": dist,
                "ring_slots": slots,
                "dma_issues": n_tiles,
                "bytes_per_dma": bk * 128 * 4,
                "vmem_ring_bytes": slots * bk * 128 * 4,
                "overlapped": dist > 0,
                "matches_oracle": ok,
            }
        )
    C.print_table("streamed matmul DMA schedule (paper §3.1 knobs, kernel level)",
                  rows, ["distance", "ring_slots", "dma_issues", "bytes_per_dma",
                         "vmem_ring_bytes", "overlapped", "matches_oracle"])
    C.save_rows("kernel_streaming", rows)

    host_rows = host_stream_sweep()
    by = {r["distance"]: r for r in host_rows}
    auto_beats_d1 = by[AUTO]["steady_wait_s"] < by[1]["steady_wait_s"]
    print(
        f"adaptive distance: steady-state wait {by[AUTO]['steady_wait_s']*1e3:.2f} ms "
        f"(converged window {by[AUTO]['final_distance']}) vs distance=1 "
        f"{by[1]['steady_wait_s']*1e3:.2f} ms -> {'OK' if auto_beats_d1 else 'FAIL'}"
    )
    ok = (
        all(r["matches_oracle"] for r in rows)
        and all(r["matches_eager"] for r in host_rows)
        and auto_beats_d1
    )
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
